//! Multi-stream batched streaming engine (DESIGN.md §6).
//!
//! The paper's §4 "farm" kernels are built for the batch 1–4 regime, but a
//! single utterance only ever exercises batch 1 on the recurrent path.
//! This module recovers the missing batch dimension from **concurrency**:
//! a [`StreamPool`] owns up to N live decode sessions and lock-steps their
//! GRU recurrent steps into one batch-m [`crate::kernels::qgemm_farm_rows`]
//! (or [`crate::kernels::gemm_f32`]) call per layer per timestep, so the
//! big recurrent weight matrix streams through cache once for all m
//! streams instead of once per stream.  Because the pool drives the same
//! `rec_gates` primitive as the single-stream engine, it inherits the
//! small-batch specializations for free: the fused GRU-gate kernel over
//! gate-interleaved panels ([`Engine::set_fused_gates`], on by default)
//! and, when only one stream is live, the dedicated m = 1 GEMV path.
//!
//! Correctness contract: pooled decoding is **bit-identical** to running
//! each session alone through [`Engine::transcribe`].  This holds because
//! the pool re-drives the same staged engine primitives (`frontend` →
//! `nonrec_block` → `rec_gates` + `gru_cell` → `head`) and because the
//! int8 recurrent path quantizes activations *per row*, so stream i's
//! dynamic scale never depends on its pool neighbours (see
//! `rust/tests/stream_pool.rs`).
//!
//! Like the single-stream engine, the pool is a **plan/executor split**:
//! the shared engine plan (prepared weights + backend) never changes at
//! serve time, and all per-block buffers — the gather matrix, per-stream
//! gate/activation tensors, quantization panels — live in a pool-level
//! scratch arena reused across blocks, so the lock-stepped hot loop does
//! no per-timestep allocations (`rust/tests/alloc_free.rs` tracks the
//! arena's growth counters).
//!
//! Session lifecycle: [`StreamPool::open`] claims a slot,
//! [`StreamPool::push_frames`] buffers raw feature frames,
//! [`StreamPool::pump`] advances every stream that has a full time-batched
//! block (padding the batch down as streams starve and retiring them as
//! utterances end), [`StreamPool::poll`] drains finished log-prob rows,
//! and [`StreamPool::close`] flushes the tail and frees the slot for the
//! next utterance.  [`crate::serve::stream_serve`] drives this API under a
//! Poisson arrival process; `benches/stream_pool.rs` measures it.

use std::sync::Arc;

use crate::data::labels_to_text;
use crate::decoder::{greedy_step, BLANK};
use crate::error::{Error, Result};
use crate::infer::{block_confidence, gru_cell, Breakdown, Engine, Scratch, StreamState};
use crate::model::ParamSet;
use crate::obs::{self, SpanSet, Stage};
use crate::prng::Pcg64;
use crate::runtime::ModelDims;
use crate::tensor::Tensor;

/// Opaque handle to a live decode session in a [`StreamPool`].
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct StreamId(u64);

/// Confidence-gated cascade configuration for a [`StreamPool`]
/// (DESIGN.md §11): every block decodes on the pool's own (low-rung)
/// engine first; blocks whose worst-frame confidence
/// ([`crate::infer::block_confidence`]) falls strictly below
/// `threshold` rewind to the block-boundary hidden checkpoint and
/// re-run on `high`.
#[derive(Clone)]
pub struct CascadeCfg {
    /// the high-fidelity rung escalated blocks re-run on
    pub high: Arc<Engine>,
    /// worst-frame confidence below which a block escalates: 0 never
    /// escalates (bit-identical to the low rung alone), ∞ always does
    /// (bit-identical to the high rung alone)
    pub threshold: f64,
    /// both rungs share a byte-identical frontend — true within a
    /// ladder, where conv and the output projection are never factored
    /// (paper §3.2) and quantization is deterministic — so escalated
    /// blocks reuse the low rung's frontend activations; false
    /// recomputes the frontend on `high` from the saved raw chunk
    pub shared_frontend: bool,
}

/// Lifetime counters for a pool (feeds the serving report and benches).
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// lock-stepped blocks processed by [`StreamPool::pump`]
    pub blocks: u64,
    /// pooled recurrent GEMM calls (one per layer per timestep per block)
    pub pooled_gemms: u64,
    /// total stream-rows carried by those GEMMs
    pub pooled_rows: u64,
    pub opened: u64,
    pub closed: u64,
    /// per-stream blocks decoded through a cascade low rung (zero unless
    /// the pool has a [`CascadeCfg`])
    pub stream_blocks: u64,
    /// cascade blocks whose worst-frame confidence breached the
    /// threshold and re-ran on the high rung
    pub escalated_blocks: u64,
}

impl PoolStats {
    /// Mean stream-batch of the pooled recurrent GEMMs — the m that the
    /// farm kernels actually saw.
    pub fn mean_rec_batch(&self) -> f64 {
        if self.pooled_gemms == 0 {
            0.0
        } else {
            self.pooled_rows as f64 / self.pooled_gemms as f64
        }
    }

    /// Fraction of cascade blocks that escalated to the high rung (zero
    /// when the pool runs without a cascade).
    pub fn escalation_rate(&self) -> f64 {
        if self.stream_blocks == 0 {
            0.0
        } else {
            self.escalated_blocks as f64 / self.stream_blocks as f64
        }
    }

    /// Fold another pool's counters into this one (cross-shard and
    /// cross-tier aggregation for the sharded serving report).
    pub fn absorb(&mut self, o: &PoolStats) {
        self.blocks += o.blocks;
        self.pooled_gemms += o.pooled_gemms;
        self.pooled_rows += o.pooled_rows;
        self.opened += o.opened;
        self.closed += o.closed;
        self.stream_blocks += o.stream_blocks;
        self.escalated_blocks += o.escalated_blocks;
    }
}

/// Result of closing a session: final greedy transcript plus any log-prob
/// rows not yet drained by [`StreamPool::poll`].
#[derive(Clone, Debug)]
pub struct ClosedSession {
    pub id: StreamId,
    pub transcript: String,
    pub logprob_rows: Vec<Vec<f32>>,
    /// total output steps this session produced over its lifetime
    pub steps: u64,
}

/// One `pump_block` call as seen by [`StreamPool::pump_traced`]: the
/// sessions that advanced in lock-step, the output steps each produced,
/// the measured wall time and the block's self-time span delta.  The
/// shard worker maps the ids to utterance numbers and forwards the
/// record to the router for clock stamping (`obs::trace`).
#[derive(Clone, Debug, Default)]
pub struct BlockTrace {
    /// Sessions that advanced, slot order.
    pub ids: Vec<StreamId>,
    /// Output steps each advancing session produced (the engine's time
    /// batch).
    pub steps: usize,
    /// Measured wall-clock seconds of the block.
    pub secs: f64,
    /// Span self-time attributed to this block alone.
    pub spans: SpanSet,
}

/// One live session: per-stream state split from the shared engine
/// weights, plus incremental greedy-decode state.
struct Session {
    id: u64,
    state: StreamState,
    /// produced log-prob rows not yet drained by `poll`
    ready: Vec<Vec<f32>>,
    /// incremental best-path decode (matches `decoder::greedy_decode`)
    prev_label: i32,
    labels: Vec<i32>,
    steps: u64,
}

impl Session {
    /// Incremental greedy CTC step: shares the argmax with
    /// [`crate::decoder::greedy_decode`] and applies the same
    /// collapse-repeats / drop-blanks rule, so live and one-shot decoding
    /// can never drift apart.
    fn decode_row(&mut self, row: &[f32]) {
        let c = greedy_step(row);
        if c != self.prev_label && c != BLANK {
            self.labels.push(c);
        }
        self.prev_label = c;
    }

    /// Absorb a block of log-prob rows (one tensor row per output step).
    fn absorb_block(&mut self, rows: &Tensor) {
        self.steps += rows.rows() as u64;
        for r in 0..rows.rows() {
            self.decode_row(rows.row(r));
            self.ready.push(rows.row(r).to_vec());
        }
    }

    /// Absorb already-materialized rows (the close/flush path).
    fn absorb(&mut self, rows: Vec<Vec<f32>>) {
        self.steps += rows.len() as u64;
        for r in &rows {
            self.decode_row(r);
        }
        self.ready.extend(rows);
    }

    /// Absorb the block left in this session's **own** scratch arena —
    /// the cascade close path, where `run_chunk` decoded into the
    /// session's arena instead of the pool's.  Inlines `decode_row` to
    /// split the borrow between the scratch (read) and the decode state
    /// (written); same collapse-repeats / drop-blanks rule.
    fn absorb_own_block(&mut self) {
        let Session { state, ready, prev_label, labels, steps, .. } = self;
        let rows = state.scratch.logp();
        *steps += rows.rows() as u64;
        for r in 0..rows.rows() {
            let c = greedy_step(rows.row(r));
            if c != *prev_label && c != BLANK {
                labels.push(c);
            }
            *prev_label = c;
            ready.push(rows.row(r).to_vec());
        }
    }
}

/// [`Session::absorb_own_block`] with the same obs decode-span
/// accounting as the pooled absorb sites.
fn absorb_own_block_timed(sess: &mut Session, bd: &mut Breakdown) {
    if obs::enabled() {
        let t0 = std::time::Instant::now();
        sess.absorb_own_block();
        bd.spans.add(Stage::Decode, t0.elapsed().as_secs_f64());
    } else {
        sess.absorb_own_block();
    }
}

/// The pool-level scratch arena: the single-stream [`Scratch`] buffer
/// set (staging chunk, quantization panels, frontend ping-pong, gate and
/// head tensors) plus the batch-row buffers only the lock-stepped
/// executor needs.  `xs`/`gxs`/`outs` are indexed by batch row (not
/// slot), so an m-stream round touches exactly m of each.  Reused across
/// `pump` calls.
struct PoolScratch {
    /// the engine-shaped buffers, shared with the single-stream executor
    eng: Scratch,
    /// slot indices of the sessions advancing this round
    ready: Vec<usize>,
    /// per-row block activations (frontend output, then layer outputs)
    xs: Vec<Tensor>,
    /// per-row non-recurrent gate pre-activations of the current layer
    gxs: Vec<Tensor>,
    /// per-row per-layer outputs (swapped into `xs` after each layer)
    outs: Vec<Tensor>,
    /// the (m, H) gathered hidden matrix of the pooled recurrent GEMM
    hmat: Tensor,
    /// per-row frontend activations of the current block — kept aside so
    /// escalated rows re-enter the GRU stack on the high rung without
    /// re-running the shared conv frontend (cascade pools only)
    fronts: Vec<Tensor>,
    /// per-row block log-prob rows: the cascade defers greedy decode
    /// past the escalation decision, so a rewind never has to undo
    /// decode state (cascade pools only)
    logps: Vec<Tensor>,
    /// per-row raw-chunk copies (cascade with an unshared frontend only)
    raws: Vec<Vec<f32>>,
    /// batch-row selector of the current stack pass: all rows for the
    /// low-rung pass, then the escalated subset for the high-rung pass
    sel: Vec<usize>,
    high_water: usize,
    grow_events: u64,
}

impl PoolScratch {
    fn with_capacity(capacity: usize) -> PoolScratch {
        PoolScratch {
            eng: Scratch::default(),
            ready: Vec::with_capacity(capacity),
            xs: (0..capacity).map(|_| Tensor::default()).collect(),
            gxs: (0..capacity).map(|_| Tensor::default()).collect(),
            outs: (0..capacity).map(|_| Tensor::default()).collect(),
            hmat: Tensor::default(),
            fronts: (0..capacity).map(|_| Tensor::default()).collect(),
            logps: (0..capacity).map(|_| Tensor::default()).collect(),
            raws: (0..capacity).map(|_| Vec::new()).collect(),
            sel: Vec::with_capacity(capacity),
            high_water: 0,
            grow_events: 0,
        }
    }

    fn footprint_bytes(&self) -> usize {
        let tensors: usize = self
            .xs
            .iter()
            .chain(&self.gxs)
            .chain(&self.outs)
            .chain(&self.fronts)
            .chain(&self.logps)
            .chain([&self.hmat])
            .map(|t| t.capacity() * 4)
            .sum();
        let raws: usize = self.raws.iter().map(|r| r.capacity() * 4).sum();
        self.eng.footprint_bytes()
            + tensors
            + raws
            + (self.ready.capacity() + self.sel.capacity()) * 8
    }

    fn settle(&mut self) {
        let fp = self.footprint_bytes();
        if fp > self.high_water {
            if self.high_water > 0 {
                self.grow_events += 1;
            }
            self.high_water = fp;
        }
    }
}

/// N concurrent decode sessions sharing one [`Engine`], with the
/// recurrent GEMMs of all runnable sessions executed as a single batch-m
/// call per layer per timestep.
pub struct StreamPool {
    engine: Arc<Engine>,
    slots: Vec<Option<Session>>,
    scratch: PoolScratch,
    next_id: u64,
    pub stats: PoolStats,
    /// confidence-gated escalation to a higher rung (DESIGN.md §11);
    /// `None` keeps the single-rung fast path byte-for-byte what it was
    cascade: Option<CascadeCfg>,
    /// sessions that escalated since the last [`Self::clear_escalations`]
    /// — the shard worker drains this every tick into journal events
    escalated: Vec<StreamId>,
}

impl StreamPool {
    /// Create a pool with `capacity` session slots over a shared engine.
    pub fn new(engine: Arc<Engine>, capacity: usize) -> StreamPool {
        assert!(capacity >= 1, "StreamPool needs at least one slot");
        StreamPool {
            engine,
            slots: (0..capacity).map(|_| None).collect(),
            scratch: PoolScratch::with_capacity(capacity),
            next_id: 0,
            stats: PoolStats::default(),
            cascade: None,
            escalated: Vec::with_capacity(2 * capacity),
        }
    }

    /// Configure confidence-gated cascade decoding: this pool's own
    /// engine becomes the low rung and `cfg.high` the escalation target.
    /// Rejects rung pairs whose layer maps disagree (a hidden-state
    /// checkpoint must mean the same thing on both rungs) and
    /// non-finite-ordered thresholds (`NaN`, negative).
    pub fn set_cascade(&mut self, cfg: CascadeCfg) -> Result<()> {
        if !self.engine.state_compatible(&cfg.high) {
            return Err(Error::Shape(
                "cascade rungs have incompatible layer maps (hidden widths, conv stack, \
                 time batch and head dims must all agree)"
                    .into(),
            ));
        }
        if cfg.threshold.is_nan() || cfg.threshold < 0.0 {
            return Err(Error::Config(format!(
                "cascade escalation threshold must be >= 0 (got {})",
                cfg.threshold
            )));
        }
        self.cascade = Some(cfg);
        Ok(())
    }

    /// Builder form of [`Self::set_cascade`].
    pub fn with_cascade(mut self, cfg: CascadeCfg) -> Result<StreamPool> {
        self.set_cascade(cfg)?;
        Ok(self)
    }

    /// The active cascade configuration, if any.
    pub fn cascade(&self) -> Option<&CascadeCfg> {
        self.cascade.as_ref()
    }

    /// Retune the escalation threshold of an active cascade — the
    /// fidelity controller's knob under SLO pressure
    /// ([`crate::controller`]): lowering it keeps more blocks on the
    /// cheap rung.
    pub fn set_escalation_threshold(&mut self, threshold: f64) -> Result<()> {
        if threshold.is_nan() || threshold < 0.0 {
            return Err(Error::Config(format!(
                "cascade escalation threshold must be >= 0 (got {threshold})"
            )));
        }
        match &mut self.cascade {
            Some(cc) => {
                cc.threshold = threshold;
                Ok(())
            }
            None => Err(Error::other("set_escalation_threshold: pool has no cascade configured")),
        }
    }

    /// Sessions that escalated at least one block since the last
    /// [`Self::clear_escalations`] (one entry per escalated block, in
    /// decode order).
    pub fn escalations(&self) -> &[StreamId] {
        &self.escalated
    }

    /// Reset the escalation queue (keeps its capacity — the shard worker
    /// calls this every tick, so the queue never grows unbounded).
    pub fn clear_escalations(&mut self) {
        self.escalated.clear();
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Live sessions currently occupying slots.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn is_full(&self) -> bool {
        self.active() == self.capacity()
    }

    /// Fraction of slots occupied — the load signal the fidelity
    /// controller ([`crate::controller`]) compares against its
    /// high/low-water marks.
    pub fn occupancy_frac(&self) -> f64 {
        self.active() as f64 / self.capacity() as f64
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Bytes reserved by the pool-level scratch arena.
    pub fn scratch_footprint(&self) -> usize {
        self.scratch.footprint_bytes()
    }

    /// Post-warmup growth events of the pool-level arena — zero once
    /// every pool batch size has been seen (the allocation-discipline
    /// counter of `rust/tests/alloc_free.rs`).
    pub fn scratch_grow_events(&self) -> u64 {
        self.scratch.grow_events
    }

    /// Claim a free slot for a new utterance stream.
    pub fn open(&mut self) -> Result<StreamId> {
        let slot = self
            .slots
            .iter()
            .position(|s| s.is_none())
            .ok_or_else(|| Error::other("stream pool full"))?;
        let id = self.next_id;
        self.next_id += 1;
        self.slots[slot] = Some(Session {
            id,
            state: self.engine.new_state(),
            ready: Vec::new(),
            prev_label: -1,
            labels: Vec::new(),
            steps: 0,
        });
        self.stats.opened += 1;
        Ok(StreamId(id))
    }

    fn index_of(&self, id: StreamId) -> Result<usize> {
        self.slots
            .iter()
            .position(|s| s.as_ref().is_some_and(|s| s.id == id.0))
            .ok_or_else(|| Error::other(format!("no such stream session {:?}", id)))
    }

    /// Buffer raw feature frames for one session (any chunk size; must be
    /// whole frames).
    pub fn push_frames(&mut self, id: StreamId, frames: &[f32]) -> Result<()> {
        if frames.len() % self.engine.feat_dim() != 0 {
            return Err(Error::Shape(format!(
                "push_frames: {} values is not a whole number of {}-dim frames",
                frames.len(),
                self.engine.feat_dim()
            )));
        }
        let idx = self.index_of(id)?;
        let sess = self.slots[idx].as_mut().unwrap();
        sess.state.buf.extend_from_slice(frames);
        Ok(())
    }

    /// Drain log-prob rows produced since the last poll.
    pub fn poll(&mut self, id: StreamId) -> Result<Vec<Vec<f32>>> {
        let idx = self.index_of(id)?;
        Ok(std::mem::take(&mut self.slots[idx].as_mut().unwrap().ready))
    }

    /// Current greedy transcript (partial while the session is live).
    pub fn transcript(&self, id: StreamId) -> Result<String> {
        let idx = self.index_of(id)?;
        Ok(labels_to_text(&self.slots[idx].as_ref().unwrap().labels))
    }

    /// Advance every session that has at least one full time-batched block
    /// buffered, lock-stepping their recurrent steps into batch-m GEMMs.
    /// Repeats until no session has a full block; returns the total number
    /// of output steps produced.  Sessions without a full block simply sit
    /// out the round (the batch pads down), and closed sessions have
    /// already retired — this is the pad/retire behaviour of §4's dynamic
    /// batching, applied to the embedded path.
    pub fn pump(&mut self, bd: &mut Breakdown) -> Result<usize> {
        let mut produced = 0;
        loop {
            let n = self.pump_block(bd)?;
            if n == 0 {
                return Ok(produced);
            }
            produced += n;
        }
    }

    /// [`StreamPool::pump`] with per-block trace records: each
    /// `pump_block` call appends one [`BlockTrace`] to `out` naming the
    /// sessions that advanced, the steps each produced, the measured
    /// wall time of the block and its span delta (`SpanSet` is `Copy`,
    /// so the delta is a before/after snapshot subtraction — the pool's
    /// breakdown keeps accumulating exactly as in the plain path).
    ///
    /// Only the shard worker calls this, and only with obs on; the plain
    /// `pump` path stays byte-for-byte what it was, so the obs-off cost
    /// contract is untouched.
    pub fn pump_traced(&mut self, bd: &mut Breakdown, out: &mut Vec<BlockTrace>) -> Result<usize> {
        let mut produced = 0;
        loop {
            let before = bd.spans;
            let t0 = std::time::Instant::now();
            let n = self.pump_block(bd)?;
            if n == 0 {
                return Ok(produced);
            }
            // `scratch.ready` still names the slots that advanced in the
            // block that just ran (it is only rewritten by the next call)
            let ids = self
                .scratch
                .ready
                .iter()
                .map(|&si| StreamId(self.slots[si].as_ref().unwrap().id))
                .collect::<Vec<_>>();
            out.push(BlockTrace {
                steps: n / ids.len(),
                ids,
                secs: t0.elapsed().as_secs_f64(),
                spans: bd.spans.delta_from(&before),
            });
            produced += n;
        }
    }

    /// One lock-stepped block across all runnable sessions.  All buffers
    /// come from the pool-level scratch arena; the per-timestep loop
    /// performs no heap allocations in steady state.
    fn pump_block(&mut self, bd: &mut Breakdown) -> Result<usize> {
        if self.cascade.is_some() {
            return self.pump_block_cascade(bd);
        }
        let StreamPool { engine, slots, scratch: ps, stats, .. } = self;
        let block_raw = engine.block_raw_len();
        ps.ready.clear();
        for (i, s) in slots.iter().enumerate() {
            if s.as_ref().is_some_and(|s| s.state.buf.len() >= block_raw) {
                ps.ready.push(i);
            }
        }
        if ps.ready.is_empty() {
            return Ok(0);
        }
        let m = ps.ready.len();
        let t = engine.time_batch;
        let feat = engine.feat_dim();

        // frontend runs per stream (it is non-recurrent and time-batched
        // by nature); this also accounts the raw frames like `stream` does
        for (row, &si) in ps.ready.iter().enumerate() {
            let sess = slots[si].as_mut().unwrap();
            ps.eng.chunk.resize(block_raw, 0.0);
            ps.eng.chunk.copy_from_slice(&sess.state.buf[..block_raw]);
            sess.state.buf.drain(..block_raw);
            bd.frames += (block_raw / feat) as u64;
            let Scratch { chunk, qs, mid, a, b, .. } = &mut ps.eng;
            engine.frontend_into(chunk, qs, mid, a, b, bd);
            // copy (not swap) the result out: keeping every buffer's role
            // fixed bounds arena warmup at two rounds, and the copy is
            // tiny next to the layer GEMMs
            let (fr, fc) = (a.rows(), a.cols());
            ps.xs[row].reset(&[fr, fc]);
            ps.xs[row].data_mut().copy_from_slice(ps.eng.a.data());
        }

        // GRU stack: per-stream time-batched nonrec, then the pooled
        // recurrent steps — ONE batch-m GEMM per layer per timestep.
        // The gather matrix and hidden states are written in place so the
        // hot loop performs no per-step allocations.
        for li in 0..engine.num_gru_layers() {
            let h_dim = engine.gru_hidden(li);
            for row in 0..m {
                engine.nonrec_block_into(
                    li,
                    &ps.xs[row],
                    &mut ps.eng.qs,
                    &mut ps.eng.mid,
                    &mut ps.gxs[row],
                    bd,
                );
                ps.outs[row].reset(&[t, h_dim]);
            }
            ps.hmat.reset(&[m, h_dim]);
            for step in 0..t {
                for (row, &si) in ps.ready.iter().enumerate() {
                    ps.hmat
                        .row_mut(row)
                        .copy_from_slice(slots[si].as_ref().unwrap().state.h[li].data());
                }
                engine.rec_gates_into(
                    li,
                    &ps.hmat,
                    &mut ps.eng.qs,
                    &mut ps.eng.mid,
                    &mut ps.eng.gh,
                    bd,
                );
                stats.pooled_gemms += 1;
                stats.pooled_rows += m as u64;

                let t2 = std::time::Instant::now();
                for (row, &si) in ps.ready.iter().enumerate() {
                    let sess = slots[si].as_mut().unwrap();
                    gru_cell(
                        ps.gxs[row].row(step),
                        ps.eng.gh.row(row),
                        sess.state.h[li].data(),
                        ps.outs[row].row_mut(step),
                    );
                    // in-place hidden update — the pooled counterpart of
                    // the engine's double-buffer swap
                    sess.state.h[li].data_mut().copy_from_slice(ps.outs[row].row(step));
                }
                let dt = t2.elapsed().as_secs_f64();
                bd.gates += dt;
                if obs::enabled() {
                    bd.spans.add(Stage::GruCell, dt);
                }
            }
            for row in 0..m {
                std::mem::swap(&mut ps.xs[row], &mut ps.outs[row]);
            }
        }

        // head + incremental decode, per stream
        let mut produced = 0;
        for (row, &si) in ps.ready.iter().enumerate() {
            let Scratch { qs, mid, fc_y, logp, .. } = &mut ps.eng;
            engine.head_into(&ps.xs[row], qs, mid, fc_y, logp, bd);
            produced += logp.rows();
            let sess = slots[si].as_mut().unwrap();
            if obs::enabled() {
                let t3 = std::time::Instant::now();
                sess.absorb_block(logp);
                bd.spans.add(Stage::Decode, t3.elapsed().as_secs_f64());
            } else {
                sess.absorb_block(logp);
            }
        }
        stats.blocks += 1;
        ps.settle();
        Ok(produced)
    }

    /// The cascade variant of [`Self::pump_block`] (DESIGN.md §11):
    /// decode the block on the low rung with per-row block-boundary
    /// checkpoints and **deferred** greedy decode, then re-run only the
    /// rows whose worst-frame confidence breached the threshold on the
    /// high rung — the escalated subset forms its own batched GEMM, so
    /// both rungs keep the one-pooled-call-per-layer-per-timestep shape.
    /// Deferring decode past the escalation decision is what makes the
    /// rewind a pure hidden-state memcpy: no greedy label, collapse
    /// state or polled row ever has to be undone.
    fn pump_block_cascade(&mut self, bd: &mut Breakdown) -> Result<usize> {
        let StreamPool { engine, slots, scratch: ps, stats, cascade, escalated, .. } = self;
        let cc = cascade.as_ref().unwrap();
        let block_raw = engine.block_raw_len();
        ps.ready.clear();
        for (i, s) in slots.iter().enumerate() {
            if s.as_ref().is_some_and(|s| s.state.buf.len() >= block_raw) {
                ps.ready.push(i);
            }
        }
        if ps.ready.is_empty() {
            return Ok(0);
        }
        let m = ps.ready.len();
        let feat = engine.feat_dim();

        // frontend per stream, snapping each row's hidden checkpoint
        // before any recurrent step can move it
        for (row, &si) in ps.ready.iter().enumerate() {
            let sess = slots[si].as_mut().unwrap();
            sess.state.snap_checkpoint();
            ps.eng.chunk.resize(block_raw, 0.0);
            ps.eng.chunk.copy_from_slice(&sess.state.buf[..block_raw]);
            sess.state.buf.drain(..block_raw);
            bd.frames += (block_raw / feat) as u64;
            if !cc.shared_frontend {
                ps.raws[row].resize(block_raw, 0.0);
                ps.raws[row].copy_from_slice(&ps.eng.chunk);
            }
            let Scratch { chunk, qs, mid, a, b, .. } = &mut ps.eng;
            engine.frontend_into(chunk, qs, mid, a, b, bd);
            let (fr, fc) = (a.rows(), a.cols());
            ps.fronts[row].reset(&[fr, fc]);
            ps.fronts[row].data_mut().copy_from_slice(ps.eng.a.data());
        }

        // low-rung pass over every row; log-probs land in ps.logps
        let mut sel = std::mem::take(&mut ps.sel);
        sel.clear();
        sel.extend(0..m);
        Self::stack_and_head(engine, slots, ps, &sel, bd, stats)?;

        // escalation decision — strictly-below keeps threshold 0 ==
        // pure low rung, and every finite confidence < ∞ keeps
        // threshold ∞ == pure high rung
        sel.clear();
        for row in 0..m {
            if block_confidence(&ps.logps[row]) < cc.threshold {
                sel.push(row);
            }
        }
        if !sel.is_empty() {
            stats.escalated_blocks += sel.len() as u64;
            for &row in &sel {
                let si = ps.ready[row];
                let sess = slots[si].as_mut().unwrap();
                // rewind is a memcpy back to the block boundary
                sess.state.rewind_to_checkpoint();
                escalated.push(StreamId(sess.id));
                if !cc.shared_frontend {
                    // rungs with different frontend weights: recompute
                    // this row's frontend on the high rung from the
                    // saved raw chunk
                    ps.eng.chunk.resize(block_raw, 0.0);
                    ps.eng.chunk.copy_from_slice(&ps.raws[row]);
                    let Scratch { chunk, qs, mid, a, b, .. } = &mut ps.eng;
                    cc.high.frontend_into(chunk, qs, mid, a, b, bd);
                    let (fr, fc) = (a.rows(), a.cols());
                    ps.fronts[row].reset(&[fr, fc]);
                    ps.fronts[row].data_mut().copy_from_slice(ps.eng.a.data());
                }
            }
            // the escalated subset re-decodes as its own batched GEMM
            Self::stack_and_head(&cc.high, slots, ps, &sel, bd, stats)?;
        }
        if obs::enabled() {
            obs::counters::record_cascade(m as u64, sel.len() as u64);
        }

        // deferred decode: absorb every row's buffered block exactly once
        let mut produced = 0;
        for (row, &si) in ps.ready.iter().enumerate() {
            let sess = slots[si].as_mut().unwrap();
            produced += ps.logps[row].rows();
            if obs::enabled() {
                let t3 = std::time::Instant::now();
                sess.absorb_block(&ps.logps[row]);
                bd.spans.add(Stage::Decode, t3.elapsed().as_secs_f64());
            } else {
                sess.absorb_block(&ps.logps[row]);
            }
        }
        stats.blocks += 1;
        stats.stream_blocks += m as u64;
        ps.sel = sel;
        ps.settle();
        Ok(produced)
    }

    /// One GRU-stack + head pass on `engine` over the batch rows named
    /// by `sel` (indices into `ps.ready`), reading each row's frontend
    /// activations from `ps.fronts` and leaving its block log-prob rows
    /// in `ps.logps` — greedy decode is the caller's job, after the
    /// escalation decision.  The recurrent steps of all selected rows
    /// run as one batch-|sel| GEMM per layer per timestep, exactly like
    /// the plain pooled path (per-row activation scales keep the result
    /// independent of the batch composition, so the threshold-∞ endpoint
    /// is bit-identical to a pure high-rung pool).
    fn stack_and_head(
        engine: &Engine,
        slots: &mut [Option<Session>],
        ps: &mut PoolScratch,
        sel: &[usize],
        bd: &mut Breakdown,
        stats: &mut PoolStats,
    ) -> Result<()> {
        let m = sel.len();
        let t = engine.time_batch;
        for &row in sel {
            let (fr, fc) = (ps.fronts[row].rows(), ps.fronts[row].cols());
            ps.xs[row].reset(&[fr, fc]);
            ps.xs[row].data_mut().copy_from_slice(ps.fronts[row].data());
        }
        for li in 0..engine.num_gru_layers() {
            let h_dim = engine.gru_hidden(li);
            for &row in sel {
                engine.nonrec_block_into(
                    li,
                    &ps.xs[row],
                    &mut ps.eng.qs,
                    &mut ps.eng.mid,
                    &mut ps.gxs[row],
                    bd,
                );
                ps.outs[row].reset(&[t, h_dim]);
            }
            ps.hmat.reset(&[m, h_dim]);
            for step in 0..t {
                for (k, &row) in sel.iter().enumerate() {
                    let si = ps.ready[row];
                    ps.hmat
                        .row_mut(k)
                        .copy_from_slice(slots[si].as_ref().unwrap().state.h[li].data());
                }
                engine.rec_gates_into(
                    li,
                    &ps.hmat,
                    &mut ps.eng.qs,
                    &mut ps.eng.mid,
                    &mut ps.eng.gh,
                    bd,
                );
                stats.pooled_gemms += 1;
                stats.pooled_rows += m as u64;

                let t2 = std::time::Instant::now();
                for (k, &row) in sel.iter().enumerate() {
                    let si = ps.ready[row];
                    let sess = slots[si].as_mut().unwrap();
                    gru_cell(
                        ps.gxs[row].row(step),
                        ps.eng.gh.row(k),
                        sess.state.h[li].data(),
                        ps.outs[row].row_mut(step),
                    );
                    sess.state.h[li].data_mut().copy_from_slice(ps.outs[row].row(step));
                }
                let dt = t2.elapsed().as_secs_f64();
                bd.gates += dt;
                if obs::enabled() {
                    bd.spans.add(Stage::GruCell, dt);
                }
            }
            for &row in sel {
                std::mem::swap(&mut ps.xs[row], &mut ps.outs[row]);
            }
        }
        for &row in sel {
            let Scratch { qs, mid, fc_y, logp, .. } = &mut ps.eng;
            engine.head_into(&ps.xs[row], qs, mid, fc_y, logp, bd);
            let (lr, lc) = (logp.rows(), logp.cols());
            ps.logps[row].reset(&[lr, lc]);
            ps.logps[row].data_mut().copy_from_slice(logp.data());
        }
        Ok(())
    }

    /// Close **every** live session, in slot order, returning each
    /// session's final transcript — the graceful-drain path of the
    /// sharded runtime (DESIGN.md §9): when a shard worker is told to
    /// stop while streams are still open (router abort, serve error),
    /// the pool flushes their padded tails exactly like [`Self::close`]
    /// would instead of dropping hidden state mid-utterance.
    pub fn drain(&mut self, bd: &mut Breakdown) -> Result<Vec<ClosedSession>> {
        let ids: Vec<StreamId> = self
            .slots
            .iter()
            .flatten()
            .map(|s| StreamId(s.id))
            .collect();
        ids.into_iter().map(|id| self.close(id, bd)).collect()
    }

    /// End a session: drain its remaining full blocks, flush the padded
    /// tail (exactly like [`Engine::flush`] on a lone stream), free the
    /// slot, and return the final transcript + undrained rows.
    pub fn close(&mut self, id: StreamId, bd: &mut Breakdown) -> Result<ClosedSession> {
        let idx = self.index_of(id)?;
        let mut sess = self.slots[idx].take().unwrap();
        // frames still buffered were never counted by `pump` (it accounts
        // per drained block); count them here so Breakdown::frames matches
        // the sequential engine exactly
        bd.frames += (sess.state.buf.len() / self.engine.feat_dim()) as u64;
        if let Some(cc) = self.cascade.clone() {
            self.close_cascade_session(&cc, &mut sess, bd)?;
        } else {
            let mut rows = self.engine.stream(&mut sess.state, &[], bd)?;
            rows.extend(self.engine.flush(&mut sess.state, bd)?);
            if obs::enabled() {
                let t0 = std::time::Instant::now();
                sess.absorb(rows);
                bd.spans.add(Stage::Decode, t0.elapsed().as_secs_f64());
            } else {
                sess.absorb(rows);
            }
        }
        self.stats.closed += 1;
        Ok(ClosedSession {
            id,
            transcript: labels_to_text(&sess.labels),
            logprob_rows: sess.ready,
            steps: sess.steps,
        })
    }

    /// Drain a closing session's remaining full blocks and padded tail
    /// through the cascade, single-stream: the same checkpoint → low
    /// decode → confidence → rewind + high re-run contract as the pooled
    /// path, so the threshold endpoints stay bit-identical through end
    /// of stream.  Escalation re-runs the chunk still staged in the
    /// session's own arena (`run_chunk` never touches it), so the high
    /// rung restages nothing.
    fn close_cascade_session(
        &mut self,
        cc: &CascadeCfg,
        sess: &mut Session,
        bd: &mut Breakdown,
    ) -> Result<()> {
        let block_raw = self.engine.block_raw_len();
        // remaining full blocks (close can run ahead of pump)
        while sess.state.buf.len() >= block_raw {
            sess.state.snap_checkpoint();
            {
                let StreamState { h, buf, scratch } = &mut sess.state;
                scratch.chunk.resize(block_raw, 0.0);
                scratch.chunk.copy_from_slice(&buf[..block_raw]);
                buf.drain(..block_raw);
                self.engine.run_chunk(h, scratch, bd)?;
            }
            Self::maybe_escalate_staged(cc, sess, bd, &mut self.stats, &mut self.escalated)?;
            absorb_own_block_timed(sess, bd);
        }
        // padded tail, exactly like Engine::flush but cascaded
        if !sess.state.buf.is_empty() {
            sess.state.snap_checkpoint();
            {
                let raw_per_step = self.engine.step_raw_len();
                let StreamState { h, buf, scratch } = &mut sess.state;
                let steps = buf.len().div_ceil(raw_per_step);
                scratch.chunk.resize(buf.len(), 0.0);
                scratch.chunk.copy_from_slice(buf);
                scratch.chunk.resize(steps * raw_per_step, 0.0);
                buf.clear();
                self.engine.run_chunk(h, scratch, bd)?;
            }
            Self::maybe_escalate_staged(cc, sess, bd, &mut self.stats, &mut self.escalated)?;
            absorb_own_block_timed(sess, bd);
        }
        Ok(())
    }

    /// Confidence-check the block `run_chunk` just left in the session's
    /// arena; on breach, rewind the hidden state and re-run the
    /// still-staged chunk on the high rung.  The single-stream path
    /// recomputes the frontend on the high rung unconditionally — a
    /// tail-only cost, and bit-safe whether or not the frontend is
    /// shared.
    fn maybe_escalate_staged(
        cc: &CascadeCfg,
        sess: &mut Session,
        bd: &mut Breakdown,
        stats: &mut PoolStats,
        escalated: &mut Vec<StreamId>,
    ) -> Result<()> {
        stats.stream_blocks += 1;
        let esc = block_confidence(sess.state.scratch.logp()) < cc.threshold;
        if esc {
            stats.escalated_blocks += 1;
            escalated.push(StreamId(sess.id));
            sess.state.rewind_to_checkpoint();
            let StreamState { h, scratch, .. } = &mut sess.state;
            cc.high.run_chunk(h, scratch, bd)?;
        }
        if obs::enabled() {
            obs::counters::record_cascade(1, esc as u64);
        }
        Ok(())
    }
}

// Compile-time Send+Sync audit (DESIGN.md §9): each shard worker owns
// its pools outright and runs them on a dedicated OS thread, so a pool
// (and everything inside a session) must be movable across threads.
const _: () = crate::assert_send_sync::<StreamPool>();
const _: () = crate::assert_send_sync::<ClosedSession>();

// ---------------------------------------------------------------------------
// Demo/bench scaffolding: deterministic model dims + synthetic parameters.
// ---------------------------------------------------------------------------

/// The `wsj_mini` dimensions, constructible without an artifact manifest
/// (kept in sync with `python/compile/configs.py`); used by the
/// `stream-serve` CLI demo, the stream benches and the pool tests.
pub fn demo_dims() -> ModelDims {
    ModelDims {
        feat_dim: 40,
        conv: vec![
            crate::runtime::ConvDims { context: 2, dim: 64 },
            crate::runtime::ConvDims { context: 2, dim: 96 },
        ],
        gru_dims: vec![96, 128, 160],
        fc_dim: 192,
        vocab: 29,
        total_stride: 4,
    }
}

/// Deterministic Glorot-initialized parameters in the partial-factored
/// scheme at the given rank fraction — an untrained but structurally
/// faithful model for latency/throughput work where weights don't matter.
pub fn synthetic_params(dims: &ModelDims, rank_frac: f64, seed: u64) -> ParamSet {
    let mut rng = Pcg64::seeded(seed);
    let mut p = ParamSet::new();
    let mut prev = dims.feat_dim;
    for (i, c) in dims.conv.iter().enumerate() {
        p.set(format!("conv{i}_w"), Tensor::glorot(c.dim, c.context * prev, &mut rng));
        p.set(format!("conv{i}_b"), Tensor::zeros(&[c.dim]));
        prev = c.dim;
    }
    for (i, &h) in dims.gru_dims.iter().enumerate() {
        let din = if i == 0 { dims.conv.last().unwrap().dim } else { dims.gru_dims[i - 1] };
        let r = ((h.min(din) as f64 * rank_frac) as usize).max(4);
        p.set(format!("rec{i}_u"), Tensor::glorot(3 * h, r, &mut rng));
        p.set(format!("rec{i}_v"), Tensor::glorot(r, h, &mut rng));
        p.set(format!("nonrec{i}_u"), Tensor::glorot(3 * h, r, &mut rng));
        p.set(format!("nonrec{i}_v"), Tensor::glorot(r, din, &mut rng));
        p.set(format!("gru{i}_b"), Tensor::zeros(&[3 * h]));
    }
    let last = *dims.gru_dims.last().unwrap();
    let r = ((dims.fc_dim.min(last) as f64 * rank_frac) as usize).max(4);
    p.set("fc_u", Tensor::glorot(dims.fc_dim, r, &mut rng));
    p.set("fc_v", Tensor::glorot(r, last, &mut rng));
    p.set("fc_b", Tensor::zeros(&[dims.fc_dim]));
    p.set("out_w", Tensor::glorot(dims.vocab, dims.fc_dim, &mut rng));
    p.set("out_b", Tensor::zeros(&[dims.vocab]));
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::Precision;

    fn engine(precision: Precision) -> Arc<Engine> {
        let dims = demo_dims();
        let p = synthetic_params(&dims, 0.5, 7);
        Arc::new(Engine::from_params(&dims, "partial", &p, precision, 4).unwrap())
    }

    #[test]
    fn pool_of_one_matches_plain_engine() {
        let eng = engine(Precision::F32);
        let mut rng = Pcg64::seeded(1);
        let feats = Tensor::randn(&[48, 40], 0.7, &mut rng);

        let mut bd = Breakdown::default();
        let (text, rows) = eng.transcribe(&feats, &mut bd).unwrap();

        let mut pool = StreamPool::new(eng.clone(), 1);
        let id = pool.open().unwrap();
        pool.push_frames(id, feats.data()).unwrap();
        let mut bd2 = Breakdown::default();
        pool.pump(&mut bd2).unwrap();
        let closed = pool.close(id, &mut bd2).unwrap();

        assert_eq!(closed.transcript, text);
        assert_eq!(closed.logprob_rows.len(), rows.len());
        for (a, b) in closed.logprob_rows.iter().zip(&rows) {
            assert_eq!(a, b, "pool-of-1 must be bit-identical");
        }
        assert_eq!(bd2.frames, bd.frames);
    }

    #[test]
    fn open_close_recycles_slots() {
        let eng = engine(Precision::Int8);
        let mut pool = StreamPool::new(eng, 2);
        let a = pool.open().unwrap();
        let b = pool.open().unwrap();
        assert!(pool.is_full());
        assert!(pool.open().is_err(), "third open must fail at capacity 2");
        assert!((pool.occupancy_frac() - 1.0).abs() < 1e-12);
        let mut bd = Breakdown::default();
        pool.close(a, &mut bd).unwrap();
        assert_eq!(pool.active(), 1);
        assert!((pool.occupancy_frac() - 0.5).abs() < 1e-12);
        let c = pool.open().unwrap();
        assert_ne!(a, c, "ids are never reused");
        assert_ne!(b, c);
        assert!(pool.poll(a).is_err(), "closed session is gone");
        assert_eq!(pool.stats.opened, 3);
        assert_eq!(pool.stats.closed, 1);
    }

    #[test]
    fn pooled_gemm_batch_tracks_occupancy() {
        let eng = engine(Precision::Int8);
        let block = eng.block_raw_len();
        let mut pool = StreamPool::new(eng, 4);
        let ids: Vec<StreamId> = (0..3).map(|_| pool.open().unwrap()).collect();
        let mut rng = Pcg64::seeded(2);
        let frames = Tensor::randn(&[block / 40, 40], 0.5, &mut rng);
        for &id in &ids {
            pool.push_frames(id, frames.data()).unwrap();
        }
        let mut bd = Breakdown::default();
        let produced = pool.pump(&mut bd).unwrap();
        assert_eq!(produced, 3 * 4, "3 streams x time_batch=4 output steps");
        assert!((pool.stats.mean_rec_batch() - 3.0).abs() < 1e-9);
        // polled rows arrive and drain exactly once
        let rows = pool.poll(ids[0]).unwrap();
        assert_eq!(rows.len(), 4);
        assert!(pool.poll(ids[0]).unwrap().is_empty());
    }

    #[test]
    fn pump_traced_matches_pump_and_records_each_block() {
        let eng = engine(Precision::Int8);
        let block = eng.block_raw_len();
        let mut rng = Pcg64::seeded(3);
        let frames = Tensor::randn(&[2 * block / 40, 40], 0.5, &mut rng);

        let mut pool = StreamPool::new(eng.clone(), 4);
        let ids: Vec<StreamId> = (0..2).map(|_| pool.open().unwrap()).collect();
        for &id in &ids {
            pool.push_frames(id, frames.data()).unwrap();
        }
        let was = obs::enabled();
        obs::set_enabled(true);
        let mut bd = Breakdown::default();
        let mut traces = Vec::new();
        let produced = pool.pump_traced(&mut bd, &mut traces).unwrap();
        obs::set_enabled(was);

        // 2 sessions x 2 buffered blocks x time_batch=4 steps
        assert_eq!(produced, 2 * 2 * 4);
        assert_eq!(traces.len(), 2, "one record per lock-stepped block");
        for tr in &traces {
            assert_eq!(tr.ids, ids, "both sessions advanced in slot order");
            assert_eq!(tr.steps, 4);
            assert!(tr.secs > 0.0);
            assert!(!tr.spans.is_empty(), "block carries its span delta");
        }
        // the block deltas partition the pool's accumulated spans
        let mut sum = SpanSet::default();
        for tr in &traces {
            sum.absorb(&tr.spans);
        }
        for i in 0..crate::obs::spans::NUM_STAGES {
            assert!((sum.secs[i] - bd.spans.secs[i]).abs() < 1e-9);
            assert_eq!(sum.calls[i], bd.spans.calls[i]);
        }

        // transcripts are bit-identical to the plain pump path
        let mut plain = StreamPool::new(eng, 4);
        let pids: Vec<StreamId> = (0..2).map(|_| plain.open().unwrap()).collect();
        for &id in &pids {
            plain.push_frames(id, frames.data()).unwrap();
        }
        let mut bd2 = Breakdown::default();
        assert_eq!(plain.pump(&mut bd2).unwrap(), produced);
        for (&a, &b) in ids.iter().zip(&pids) {
            assert_eq!(pool.transcript(a).unwrap(), plain.transcript(b).unwrap());
        }
    }

    #[test]
    fn partial_block_waits_for_more_frames() {
        let eng = engine(Precision::F32);
        let step = eng.step_raw_len();
        let mut pool = StreamPool::new(eng, 2);
        let id = pool.open().unwrap();
        // one output step of frames < a full time_batch=4 block
        pool.push_frames(id, &vec![0.1; step]).unwrap();
        let mut bd = Breakdown::default();
        assert_eq!(pool.pump(&mut bd).unwrap(), 0);
        // close flushes the zero-padded tail instead
        let closed = pool.close(id, &mut bd).unwrap();
        assert_eq!(closed.logprob_rows.len(), 1);
    }

    #[test]
    fn drain_closes_every_live_session_like_close_would() {
        let eng = engine(Precision::Int8);
        let mut rng = Pcg64::seeded(4);
        let feats = Tensor::randn(&[30, 40], 0.6, &mut rng);

        // reference: two sessions closed one by one
        let mut solo = StreamPool::new(eng.clone(), 2);
        let a = solo.open().unwrap();
        let b = solo.open().unwrap();
        let mut bd1 = Breakdown::default();
        solo.push_frames(a, feats.data()).unwrap();
        solo.push_frames(b, &feats.data()[..400]).unwrap();
        let ta = solo.close(a, &mut bd1).unwrap().transcript;
        let tb = solo.close(b, &mut bd1).unwrap().transcript;

        let mut pool = StreamPool::new(eng, 2);
        let a2 = pool.open().unwrap();
        let b2 = pool.open().unwrap();
        let mut bd2 = Breakdown::default();
        pool.push_frames(a2, feats.data()).unwrap();
        pool.push_frames(b2, &feats.data()[..400]).unwrap();
        let closed = pool.drain(&mut bd2).unwrap();
        assert_eq!(closed.len(), 2);
        assert_eq!(pool.active(), 0, "drain must free every slot");
        assert_eq!(closed[0].transcript, ta);
        assert_eq!(closed[1].transcript, tb);
        assert_eq!(pool.stats.closed, 2);
        assert_eq!(bd2.frames, bd1.frames);
        // draining an empty pool is a no-op
        assert!(pool.drain(&mut bd2).unwrap().is_empty());
    }

    #[test]
    fn push_rejects_ragged_frames() {
        let eng = engine(Precision::F32);
        let mut pool = StreamPool::new(eng, 1);
        let id = pool.open().unwrap();
        assert!(pool.push_frames(id, &[0.0; 41]).is_err());
    }

    #[test]
    fn pool_scratch_stops_growing_at_steady_occupancy() {
        let eng = engine(Precision::Int8);
        let block = eng.block_raw_len();
        let mut pool = StreamPool::new(eng, 3);
        let ids: Vec<StreamId> = (0..3).map(|_| pool.open().unwrap()).collect();
        let mut rng = Pcg64::seeded(9);
        let frames = Tensor::randn(&[block / 40, 40], 0.5, &mut rng);
        let mut bd = Breakdown::default();
        // warmup: two rounds at full occupancy (the layer ping-pong
        // buffers alternate roles between blocks, so both parities must
        // see their steady-state shapes)
        for _ in 0..2 {
            for &id in &ids {
                pool.push_frames(id, frames.data()).unwrap();
            }
            pool.pump(&mut bd).unwrap();
        }
        let fp = pool.scratch_footprint();
        assert!(fp > 0);
        for _ in 0..4 {
            for &id in &ids {
                pool.push_frames(id, frames.data()).unwrap();
            }
            pool.pump(&mut bd).unwrap();
        }
        assert_eq!(pool.scratch_footprint(), fp, "steady-state pump must not grow the arena");
        assert_eq!(pool.scratch_grow_events(), 0);
    }
}

//! Forward-graph builder for the factored GRU stack — the training-time
//! mirror of `infer.rs`'s layer map, op for op:
//!
//! ```text
//! feats (T, F)
//!   └─ per conv layer: stack_rows(ctx) → x·Wᵀ → +bias → ReLU
//!   └─ per GRU layer:  gx = x·Wnrᵀ + b (time-batched)
//!                      per step t: gh = h·Wrᵀ
//!                        z = σ(gx_z + gh_z)   r = σ(gx_r + gh_r)
//!                        h̃ = tanh(gx_h + r ∘ gh_h)
//!                        h = h + z ∘ (h̃ − h)          [= (1−z)h + z h̃]
//!   └─ head: x·Wfcᵀ + b → ReLU → x·Woutᵀ + b → log-softmax
//!   └─ CTC(logp, labels) → scalar loss
//! ```
//!
//! Factored groups (`{base}_u`/`{base}_v`) apply as `(x·Vᵀ)·Uᵀ`, dense
//! groups as `x·Wᵀ` — the same dispatch rule as `infer::Op::from_params`,
//! so any parameter set the embedded engine can serve, the native trainer
//! can train, and vice versa.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::model::ParamSet;
use crate::runtime::ModelDims;
use crate::tensor::Tensor;

use super::tape::{Tape, Var};

/// A built forward graph for one utterance: the tape, the log-prob output
/// var, and the trainable leaf var per parameter name.
pub struct Forward {
    pub tape: Tape,
    pub logp: Var,
    pub leaves: BTreeMap<String, Var>,
}

/// Trainable leaf for a named parameter — **one leaf per name**: the
/// recurrent weights are applied once per timestep, and every use must
/// hit the same tape node so the backward sweep sums their gradients in
/// one slot.
fn leaf_param(
    tape: &mut Tape,
    params: &ParamSet,
    leaves: &mut BTreeMap<String, Var>,
    name: &str,
) -> Result<Var> {
    if let Some(&v) = leaves.get(name) {
        return Ok(v);
    }
    let v = tape.leaf(params.get(name)?.clone(), true);
    leaves.insert(name.to_string(), v);
    Ok(v)
}

/// Weight leaf, optionally wrapped in a straight-through `fake_quant`
/// node (quantization-aware fine-tuning, `--bits 4`).  Cached per name in
/// `fq` so the recurrent weights — applied once per timestep — quantize
/// once on the tape, not once per step; gradients still land on the raw
/// leaf (the STE backward is a pass-through).
fn weight_param(
    tape: &mut Tape,
    params: &ParamSet,
    leaves: &mut BTreeMap<String, Var>,
    fq: &mut BTreeMap<String, Var>,
    qat_bits: Option<u32>,
    name: &str,
) -> Result<Var> {
    if let Some(&v) = fq.get(name) {
        return Ok(v);
    }
    let leaf = leaf_param(tape, params, leaves, name)?;
    let v = match qat_bits {
        Some(bits) => tape.fake_quant(leaf, bits),
        None => leaf,
    };
    fq.insert(name.to_string(), v);
    Ok(v)
}

/// Apply a possibly-factored group: `(x·Vᵀ)·Uᵀ` when `{base}_u` exists,
/// else `x·Wᵀ` from `{base}_w`.  Weights (never biases) go through
/// [`weight_param`], so QAT rounds exactly the tensors `ladder-build`
/// will quantize.
#[allow(clippy::too_many_arguments)]
fn apply_group(
    tape: &mut Tape,
    params: &ParamSet,
    leaves: &mut BTreeMap<String, Var>,
    fq: &mut BTreeMap<String, Var>,
    qat_bits: Option<u32>,
    base: &str,
    x: Var,
) -> Result<Var> {
    if params.contains(&format!("{base}_u")) {
        let u = weight_param(tape, params, leaves, fq, qat_bits, &format!("{base}_u"))?;
        let v = weight_param(tape, params, leaves, fq, qat_bits, &format!("{base}_v"))?;
        let mid = tape.matmul_nt(x, v);
        Ok(tape.matmul_nt(mid, u))
    } else {
        let w = weight_param(tape, params, leaves, fq, qat_bits, &format!("{base}_w"))?;
        Ok(tape.matmul_nt(x, w))
    }
}

/// Pad an utterance's feature rows with zeros to a stride boundary (the
/// same padding `Engine::flush` applies at end of utterance), so the
/// frontend's frame stacking divides evenly.
fn pad_to_stride(feats: &Tensor, stride: usize) -> Tensor {
    let (t, f) = (feats.rows(), feats.cols());
    let steps = t.div_ceil(stride);
    let mut data = feats.data().to_vec();
    data.resize(steps * stride * f, 0.0);
    Tensor::new(&[steps * stride, f], data).unwrap()
}

/// Build the forward graph for one utterance up to the log-prob rows.
pub fn build_forward(params: &ParamSet, dims: &ModelDims, feats: &Tensor) -> Result<Forward> {
    build_forward_qat(params, dims, feats, None)
}

/// [`build_forward`] with optional quantization-aware training: when
/// `qat_bits` is set, every weight matrix passes through a
/// straight-through `fake_quant` node at that width before its GEMM, so
/// the loss is computed against inference-time rounding.
pub fn build_forward_qat(
    params: &ParamSet,
    dims: &ModelDims,
    feats: &Tensor,
    qat_bits: Option<u32>,
) -> Result<Forward> {
    if feats.rank() != 2 || feats.cols() != dims.feat_dim {
        return Err(Error::Train(format!(
            "feats {:?} do not match feat_dim {}",
            feats.shape(),
            dims.feat_dim
        )));
    }
    if feats.rows() == 0 {
        return Err(Error::Train("empty utterance".into()));
    }
    let mut tape = Tape::new();
    let mut leaves = BTreeMap::new();
    let mut fq = BTreeMap::new();
    let padded = pad_to_stride(feats, dims.total_stride);
    let mut x = tape.leaf(padded, false);

    // frontend: stack-and-project conv layers (time-batched by nature)
    for (i, c) in dims.conv.iter().enumerate() {
        x = tape.stack_rows(x, c.context);
        x = apply_group(&mut tape, params, &mut leaves, &mut fq, qat_bits, &format!("conv{i}"), x)?;
        let b = leaf_param(&mut tape, params, &mut leaves, &format!("conv{i}_b"))?;
        x = tape.add_bias(x, b);
        x = tape.relu(x);
    }

    // GRU stack: time-batched non-recurrent GEMM, sequential recurrence
    for (i, &h_dim) in dims.gru_dims.iter().enumerate() {
        let gx_raw =
            apply_group(&mut tape, params, &mut leaves, &mut fq, qat_bits, &format!("nonrec{i}"), x)?;
        let b = leaf_param(&mut tape, params, &mut leaves, &format!("gru{i}_b"))?;
        let gx = tape.add_bias(gx_raw, b);
        let t_steps = tape.value(gx).rows();
        let mut h = tape.leaf(Tensor::zeros(&[1, h_dim]), false);
        let mut rows = Vec::with_capacity(t_steps);
        for t in 0..t_steps {
            let gh =
                apply_group(&mut tape, params, &mut leaves, &mut fq, qat_bits, &format!("rec{i}"), h)?;
            let gxt = tape.row(gx, t);
            let (gxz, ghz) = (
                tape.slice_cols(gxt, 0, h_dim),
                tape.slice_cols(gh, 0, h_dim),
            );
            let (gxr, ghr) = (
                tape.slice_cols(gxt, h_dim, 2 * h_dim),
                tape.slice_cols(gh, h_dim, 2 * h_dim),
            );
            let (gxh, ghh) = (
                tape.slice_cols(gxt, 2 * h_dim, 3 * h_dim),
                tape.slice_cols(gh, 2 * h_dim, 3 * h_dim),
            );
            let zsum = tape.add(gxz, ghz);
            let z = tape.sigmoid(zsum);
            let rsum = tape.add(gxr, ghr);
            let r = tape.sigmoid(rsum);
            let gated = tape.mul(r, ghh);
            let csum = tape.add(gxh, gated);
            let cand = tape.tanh(csum);
            // h' = (1−z)·h + z·h̃ = h + z·(h̃ − h), the infer::gru_cell form
            let delta = tape.sub(cand, h);
            let zdelta = tape.mul(z, delta);
            h = tape.add(h, zdelta);
            rows.push(h);
        }
        x = tape.concat_rows(&rows);
    }

    // head: fc (+ReLU) → output projection → log-softmax
    x = apply_group(&mut tape, params, &mut leaves, &mut fq, qat_bits, "fc", x)?;
    let fcb = leaf_param(&mut tape, params, &mut leaves, "fc_b")?;
    x = tape.add_bias(x, fcb);
    x = tape.relu(x);
    x = apply_group(&mut tape, params, &mut leaves, &mut fq, qat_bits, "out", x)?;
    let outb = leaf_param(&mut tape, params, &mut leaves, "out_b")?;
    x = tape.add_bias(x, outb);
    let logp = tape.log_softmax(x);
    Ok(Forward { tape, logp, leaves })
}

/// Pull the per-parameter gradients out of the backward sweep's slots
/// (one leaf per name — multi-use parameters like the recurrent weights
/// already accumulated across timesteps on the tape).
fn collect_grads(fwd: &Forward, grads: &[Option<Tensor>]) -> BTreeMap<String, Tensor> {
    let mut out: BTreeMap<String, Tensor> = BTreeMap::new();
    for (name, var) in &fwd.leaves {
        if let Some(g) = &grads[var.0] {
            out.insert(name.clone(), g.clone());
        }
    }
    out
}

/// Loss + parameter gradients for a single utterance.
pub fn utterance_grads(
    params: &ParamSet,
    dims: &ModelDims,
    feats: &Tensor,
    labels: &[i32],
) -> Result<(f32, BTreeMap<String, Tensor>)> {
    utterance_grads_qat(params, dims, feats, labels, None)
}

/// [`utterance_grads`] with optional straight-through fake quantization
/// of the weights (see [`build_forward_qat`]).
pub fn utterance_grads_qat(
    params: &ParamSet,
    dims: &ModelDims,
    feats: &Tensor,
    labels: &[i32],
    qat_bits: Option<u32>,
) -> Result<(f32, BTreeMap<String, Tensor>)> {
    let mut fwd = build_forward_qat(params, dims, feats, qat_bits)?;
    let loss_var = fwd.tape.ctc(fwd.logp, labels)?;
    let loss = fwd.tape.value(loss_var).data()[0];
    let grads = fwd.tape.backward(loss_var);
    Ok((loss, collect_grads(&fwd, &grads)))
}

/// Mean CTC loss and mean parameter gradients over a batch of
/// `(feats, labels)` utterances (the padded-batch rows of
/// [`crate::data::Batch::utterances`]).
pub fn batch_ctc_grads(
    params: &ParamSet,
    dims: &ModelDims,
    utts: &[(Tensor, Vec<i32>)],
) -> Result<(f32, ParamSet)> {
    batch_ctc_grads_qat(params, dims, utts, None)
}

/// [`batch_ctc_grads`] with optional straight-through fake quantization
/// of the weights (`train --stage 2 --bits 4`).
pub fn batch_ctc_grads_qat(
    params: &ParamSet,
    dims: &ModelDims,
    utts: &[(Tensor, Vec<i32>)],
    qat_bits: Option<u32>,
) -> Result<(f32, ParamSet)> {
    if utts.is_empty() {
        return Err(Error::Train("batch_ctc_grads: empty batch".into()));
    }
    let scale = 1.0 / utts.len() as f32;
    let mut grads = ParamSet::zeros_like(params);
    let mut loss_sum = 0.0f64;
    for (feats, labels) in utts {
        let (loss, ugrads) = utterance_grads_qat(params, dims, feats, labels, qat_bits)?;
        loss_sum += loss as f64;
        for (name, mut g) in ugrads {
            g.scale(scale);
            grads.get_mut(&name)?.add_assign(&g)?;
        }
    }
    Ok(((loss_sum * scale as f64) as f32, grads))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model;
    use crate::prng::Pcg64;
    use crate::runtime::ConvDims;

    fn tiny_dims() -> ModelDims {
        ModelDims {
            feat_dim: 6,
            conv: vec![ConvDims { context: 2, dim: 8 }],
            gru_dims: vec![5, 7],
            fc_dim: 9,
            vocab: 11,
            total_stride: 2,
        }
    }

    #[test]
    fn forward_shapes_and_normalization() {
        let dims = tiny_dims();
        let params = model::init_factored_full(&dims, 3);
        let mut rng = Pcg64::seeded(4);
        let feats = Tensor::randn(&[11, 6], 0.7, &mut rng); // ragged → pads to 12
        let fwd = build_forward(&params, &dims, &feats).unwrap();
        let logp = fwd.tape.value(fwd.logp);
        assert_eq!(logp.shape(), &[6, 11]); // 12 rows / stride 2
        for t in 0..logp.rows() {
            let total: f32 = logp.row(t).iter().map(|v| v.exp()).sum();
            assert!((total - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn forward_matches_inference_engine() {
        // The training forward must agree with the engine the checkpoint
        // will be served by — same layer map, same gate math.
        use crate::infer::{Breakdown, Engine, Precision};
        let dims = tiny_dims();
        let params = model::init_factored_full(&dims, 5);
        let mut rng = Pcg64::seeded(6);
        let feats = Tensor::randn(&[12, 6], 0.7, &mut rng);
        let fwd = build_forward(&params, &dims, &feats).unwrap();
        let logp = fwd.tape.value(fwd.logp);

        let eng = Engine::from_params(&dims, "partial", &params, Precision::F32, 4).unwrap();
        let mut bd = Breakdown::default();
        let (_, rows) = eng.transcribe(&feats, &mut bd).unwrap();
        assert_eq!(rows.len(), logp.rows());
        for (t, row) in rows.iter().enumerate() {
            for (a, b) in logp.row(t).iter().zip(row) {
                assert!((a - b).abs() < 1e-4, "step {t}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn batch_grads_cover_every_param() {
        let dims = tiny_dims();
        let params = model::init_factored_full(&dims, 7);
        let mut rng = Pcg64::seeded(8);
        let utts: Vec<(Tensor, Vec<i32>)> = (0..2)
            .map(|_| (Tensor::randn(&[10, 6], 0.7, &mut rng), vec![1, 2]))
            .collect();
        let (loss, grads) = batch_ctc_grads(&params, &dims, &utts).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(grads.len(), params.len());
        for (name, g) in grads.iter() {
            assert!(g.abs_max().is_finite(), "{name} grad non-finite");
        }
        // the loss pushes on every weight in the stack
        assert!(grads.get("rec0_u").unwrap().abs_max() > 0.0);
        assert!(grads.get("out_w").unwrap().abs_max() > 0.0);
    }

    #[test]
    fn qat_forward_sees_the_serving_quantizer() {
        // fake_quant(w) is exactly dequantize4(quantize4(w)), so the QAT
        // forward must agree with an f32 engine built from the rounded
        // weights — the STE trains against the rounding serve will apply
        use crate::infer::{Breakdown, Engine, Precision};
        use crate::quant::fake_quantize4;
        let dims = tiny_dims();
        let params = model::init_factored_full(&dims, 21);
        let mut rounded = ParamSet::new();
        for (name, t) in params.iter() {
            if name.ends_with("_b") {
                rounded.set(name.clone(), t.clone());
            } else {
                rounded.set(name.clone(), fake_quantize4(t));
            }
        }
        let mut rng = Pcg64::seeded(22);
        let feats = Tensor::randn(&[12, 6], 0.7, &mut rng);
        let fwd = build_forward_qat(&params, &dims, &feats, Some(4)).unwrap();
        let logp = fwd.tape.value(fwd.logp);
        let eng = Engine::from_params(&dims, "partial", &rounded, Precision::F32, 4).unwrap();
        let mut bd = Breakdown::default();
        let (_, rows) = eng.transcribe(&feats, &mut bd).unwrap();
        assert_eq!(rows.len(), logp.rows());
        for (t, row) in rows.iter().enumerate() {
            for (a, b) in logp.row(t).iter().zip(row) {
                assert!((a - b).abs() < 1e-4, "step {t}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn qat_grads_flow_through_the_ste_to_every_weight() {
        let dims = tiny_dims();
        let params = model::init_factored_full(&dims, 23);
        let mut rng = Pcg64::seeded(24);
        let utts: Vec<(Tensor, Vec<i32>)> = (0..2)
            .map(|_| (Tensor::randn(&[10, 6], 0.7, &mut rng), vec![1, 2]))
            .collect();
        let (loss, grads) = batch_ctc_grads_qat(&params, &dims, &utts, Some(4)).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(grads.len(), params.len());
        for (name, g) in grads.iter() {
            assert!(g.abs_max().is_finite(), "{name} grad non-finite");
        }
        assert!(grads.get("rec0_u").unwrap().abs_max() > 0.0);
        assert!(grads.get("conv0_w").unwrap().abs_max() > 0.0);
    }
}

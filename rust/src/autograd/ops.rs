//! Tape op set: forward constructors + backward rules.
//!
//! Every op the factored GRU stack needs (DESIGN.md §2.5): the `y = x·Wᵀ`
//! GEMM (the same contraction the embedded engine runs, so trained and
//! served layer maps match one-to-one), elementwise gate math, bias
//! broadcast, row/column slicing for the `[z | r | h̃]` gate layout,
//! row-stacking for the conv frontend, per-row log-softmax, and the CTC
//! loss as a fused node ([`Tape::ctc`], see [`super::ctc`]) that caches
//! its input gradient at forward time — the alpha/beta recursions already
//! produce it, so backward is a single saxpy.
//!
//! Backward rules live in `backward_op` (crate-private); each is the
//! textbook adjoint of the forward line directly above it in [`Tape`]'s
//! constructors.

use crate::error::{Error, Result};
use crate::kernels;
use crate::tensor::Tensor;

use super::tape::{acc, Node, Tape, Var};

/// Node operation. Aux data needed by the backward rule rides on the
/// variant (slice bounds, the cached CTC gradient).
pub(crate) enum Op {
    Leaf,
    /// `y = a @ bᵀ` — a (m,k), b (n,k) → (m,n); weights stay in their
    /// `(out, in)` storage layout exactly as `infer.rs` applies them.
    MatMulNT,
    /// elementwise `a + b`
    Add,
    /// elementwise `a - b`
    Sub,
    /// elementwise `a ∘ b`
    Mul,
    /// `x + bias` with rank-1 `bias` broadcast over rows
    AddBias,
    Sigmoid,
    Tanh,
    Relu,
    /// columns `[c0, c1)` of a rank-2 input
    SliceCols { c0: usize, c1: usize },
    /// row `r` of a rank-2 input, as a (1, n) matrix
    Row { r: usize },
    /// vertical concatenation of rank-2 inputs (equal cols)
    ConcatRows,
    /// (t, f) → (t/ctx, ctx·f) reshape — the conv frontend's frame
    /// stacking; row-major data is untouched, so backward is the inverse
    /// reshape
    StackRows,
    /// per-row log-softmax
    LogSoftmax,
    /// sum of all elements → scalar
    Sum,
    /// CTC negative log-likelihood of the input log-prob rows against a
    /// fixed label sequence; `grad` is ∂loss/∂logp cached at forward time
    Ctc { grad: Tensor },
    /// Straight-through fake quantization: forward runs the serving
    /// quantize→dequantize round trip (int4 per-group or int8 per-tensor),
    /// backward passes the gradient through unchanged — the STE that lets
    /// stage-2 fine-tuning see inference-time rounding (`--bits 4`).
    FakeQuant,
}

impl Tape {
    /// `a @ bᵀ`: a (m,k) × b (n,k) → (m,n).
    pub fn matmul_nt(&mut self, a: Var, b: Var) -> Var {
        let y = kernels::gemm_f32(self.value(a), self.value(b), None);
        self.push(Op::MatMulNT, vec![a, b], y)
    }

    pub fn add(&mut self, a: Var, b: Var) -> Var {
        let mut y = self.value(a).clone();
        y.add_assign(self.value(b)).expect("add: shape mismatch");
        self.push(Op::Add, vec![a, b], y)
    }

    pub fn sub(&mut self, a: Var, b: Var) -> Var {
        let mut y = self.value(a).clone();
        assert_eq!(y.shape(), self.value(b).shape(), "sub: shape mismatch");
        for (x, s) in y.data_mut().iter_mut().zip(self.value(b).data()) {
            *x -= s;
        }
        self.push(Op::Sub, vec![a, b], y)
    }

    pub fn mul(&mut self, a: Var, b: Var) -> Var {
        let mut y = self.value(a).clone();
        y.mul_assign(self.value(b)).expect("mul: shape mismatch");
        self.push(Op::Mul, vec![a, b], y)
    }

    /// `x + bias`, rank-1 `bias` broadcast over the rows of rank-2 `x`.
    pub fn add_bias(&mut self, x: Var, bias: Var) -> Var {
        let b = self.value(bias).data().to_vec();
        let mut y = self.value(x).clone();
        let cols = y.cols();
        assert_eq!(b.len(), cols, "add_bias: bias length mismatch");
        for row in y.data_mut().chunks_mut(cols) {
            for (v, bv) in row.iter_mut().zip(&b) {
                *v += bv;
            }
        }
        self.push(Op::AddBias, vec![x, bias], y)
    }

    pub fn sigmoid(&mut self, x: Var) -> Var {
        let mut y = self.value(x).clone();
        for v in y.data_mut() {
            *v = 1.0 / (1.0 + (-*v).exp());
        }
        self.push(Op::Sigmoid, vec![x], y)
    }

    pub fn tanh(&mut self, x: Var) -> Var {
        let mut y = self.value(x).clone();
        for v in y.data_mut() {
            *v = v.tanh();
        }
        self.push(Op::Tanh, vec![x], y)
    }

    pub fn relu(&mut self, x: Var) -> Var {
        let mut y = self.value(x).clone();
        for v in y.data_mut() {
            *v = v.max(0.0);
        }
        self.push(Op::Relu, vec![x], y)
    }

    /// Columns `[c0, c1)` of rank-2 `x`.
    pub fn slice_cols(&mut self, x: Var, c0: usize, c1: usize) -> Var {
        let xv = self.value(x);
        let (m, n) = (xv.rows(), xv.cols());
        assert!(c0 < c1 && c1 <= n, "slice_cols [{c0},{c1}) of {n}");
        let mut data = Vec::with_capacity(m * (c1 - c0));
        for i in 0..m {
            data.extend_from_slice(&xv.row(i)[c0..c1]);
        }
        let y = Tensor::new(&[m, c1 - c0], data).unwrap();
        self.push(Op::SliceCols { c0, c1 }, vec![x], y)
    }

    /// Row `r` of rank-2 `x`, as a (1, n) matrix.
    pub fn row(&mut self, x: Var, r: usize) -> Var {
        let xv = self.value(x);
        let y = Tensor::new(&[1, xv.cols()], xv.row(r).to_vec()).unwrap();
        self.push(Op::Row { r }, vec![x], y)
    }

    /// Vertical concatenation of rank-2 vars with equal column counts.
    pub fn concat_rows(&mut self, parts: &[Var]) -> Var {
        assert!(!parts.is_empty(), "concat_rows of nothing");
        let tensors: Vec<&Tensor> = parts.iter().map(|&v| self.value(v)).collect();
        let y = Tensor::concat_rows(&tensors).expect("concat_rows: col mismatch");
        self.push(Op::ConcatRows, parts.to_vec(), y)
    }

    /// (t, f) → (t/ctx, ctx·f): the conv frontend's frame stacking.
    pub fn stack_rows(&mut self, x: Var, ctx: usize) -> Var {
        let xv = self.value(x);
        let (t, f) = (xv.rows(), xv.cols());
        assert!(ctx > 0 && t % ctx == 0, "stack_rows: {t} rows not divisible by {ctx}");
        let y = xv.clone().reshape(&[t / ctx, ctx * f]).unwrap();
        self.push(Op::StackRows, vec![x], y)
    }

    /// Per-row log-softmax (same arithmetic as the inference head).
    pub fn log_softmax(&mut self, x: Var) -> Var {
        let mut y = self.value(x).clone();
        log_softmax_rows(&mut y);
        self.push(Op::LogSoftmax, vec![x], y)
    }

    /// Sum of all elements → rank-0 scalar.
    pub fn sum(&mut self, x: Var) -> Var {
        let total: f32 = self.value(x).data().iter().sum();
        self.push(Op::Sum, vec![x], Tensor::scalar(total))
    }

    /// CTC loss of log-prob rows `logp` (T, V) against `labels`
    /// (blank = 0 excluded).  Fails on infeasible (T too short) or
    /// non-finite inputs; see [`super::ctc::ctc_loss_grad`].
    pub fn ctc(&mut self, logp: Var, labels: &[i32]) -> Result<Var> {
        let (loss, grad) = super::ctc::ctc_loss_grad(self.value(logp), labels)?;
        if !loss.is_finite() {
            return Err(Error::Train(format!("CTC loss is non-finite ({loss})")));
        }
        Ok(self.push(Op::Ctc { grad }, vec![logp], Tensor::scalar(loss)))
    }

    /// Quantize-dequantize `x` through the serving quantizer for `bits`
    /// (4 = per-group int4, 8 = per-tensor int8) with a straight-through
    /// gradient.  Panics on any other bit width — callers validate at the
    /// CLI boundary.
    pub fn fake_quant(&mut self, x: Var, bits: u32) -> Var {
        let y = match bits {
            4 => crate::quant::fake_quantize4(self.value(x)),
            8 => crate::quant::fake_quantize8(self.value(x)),
            b => panic!("fake_quant supports bits 4 or 8, got {b}"),
        };
        self.push(Op::FakeQuant, vec![x], y)
    }
}

/// In-place per-row log-softmax over a rank-2 tensor — the single
/// normalization kernel shared by [`Tape::log_softmax`], the max-shifted
/// arithmetic of the inference head, and the tests/benches that need
/// valid log-prob inputs for CTC.
pub fn log_softmax_rows(x: &mut Tensor) {
    let cols = x.cols();
    for row in x.data_mut().chunks_mut(cols) {
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let lse = m + row.iter().map(|v| (v - m).exp()).sum::<f32>().ln();
        for v in row {
            *v -= lse;
        }
    }
}

/// `aᵀ @ b` without materializing the transpose — the weight-side
/// adjoint of [`Tape::matmul_nt`], computed as rank-1 row updates so
/// both operands stream in row-major order.  (A farm-tiled TN kernel in
/// `crate::kernels` would be the next step if `BENCH_train.json` shows
/// backward GEMMs dominating.)
fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, n) = (a.rows(), a.cols());
    let k = b.cols();
    debug_assert_eq!(b.rows(), m, "matmul_tn contraction mismatch");
    let mut out = Tensor::zeros(&[n, k]);
    for i in 0..m {
        let arow = a.row(i);
        let brow = b.row(i);
        for (j, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            for (o, &bv) in out.row_mut(j).iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// Backward rule dispatch: accumulate ∂loss/∂input into `lower` (the
/// gradient slots of all earlier tape nodes) given the node's own
/// gradient `g`.
pub(crate) fn backward_op(tape: &Tape, node: &Node, g: &Tensor, lower: &mut [Option<Tensor>]) {
    let input = |k: usize| -> &Tensor { tape.value(node.inputs[k]) };
    let needs = |k: usize| -> bool { tape.nodes[node.inputs[k].0].requires_grad };
    let idx = |k: usize| -> usize { node.inputs[k].0 };
    match &node.op {
        Op::Leaf => {}
        Op::MatMulNT => {
            // y = a bᵀ: da = g b, db = gᵀ a (TN form, no transpose copy)
            if needs(0) {
                let da = g.matmul(input(1)).unwrap();
                acc(&mut lower[idx(0)], da);
            }
            if needs(1) {
                acc(&mut lower[idx(1)], matmul_tn(g, input(0)));
            }
        }
        Op::Add => {
            for k in 0..2 {
                if needs(k) {
                    acc(&mut lower[idx(k)], g.clone());
                }
            }
        }
        Op::Sub => {
            if needs(0) {
                acc(&mut lower[idx(0)], g.clone());
            }
            if needs(1) {
                let mut ng = g.clone();
                ng.scale(-1.0);
                acc(&mut lower[idx(1)], ng);
            }
        }
        Op::Mul => {
            if needs(0) {
                let mut da = g.clone();
                da.mul_assign(input(1)).unwrap();
                acc(&mut lower[idx(0)], da);
            }
            if needs(1) {
                let mut db = g.clone();
                db.mul_assign(input(0)).unwrap();
                acc(&mut lower[idx(1)], db);
            }
        }
        Op::AddBias => {
            if needs(0) {
                acc(&mut lower[idx(0)], g.clone());
            }
            if needs(1) {
                let n = input(1).len();
                let mut db = vec![0.0f32; n];
                for row in g.data().chunks(n) {
                    for (d, gv) in db.iter_mut().zip(row) {
                        *d += gv;
                    }
                }
                acc(&mut lower[idx(1)], Tensor::from_vec(db));
            }
        }
        Op::Sigmoid => {
            if needs(0) {
                let mut dx = g.clone();
                for (d, &y) in dx.data_mut().iter_mut().zip(node.value.data()) {
                    *d *= y * (1.0 - y);
                }
                acc(&mut lower[idx(0)], dx);
            }
        }
        Op::Tanh => {
            if needs(0) {
                let mut dx = g.clone();
                for (d, &y) in dx.data_mut().iter_mut().zip(node.value.data()) {
                    *d *= 1.0 - y * y;
                }
                acc(&mut lower[idx(0)], dx);
            }
        }
        Op::Relu => {
            if needs(0) {
                let mut dx = g.clone();
                for (d, &y) in dx.data_mut().iter_mut().zip(node.value.data()) {
                    if y <= 0.0 {
                        *d = 0.0;
                    }
                }
                acc(&mut lower[idx(0)], dx);
            }
        }
        // Slicing backward accumulates **in place** into the input's
        // gradient slot (allocated zeroed on first touch) instead of
        // materializing a full-size sparse delta per use: the GRU loop
        // slices gx/gh once per timestep, and a per-use full-matrix
        // add would make backward O(T²) in the block length.
        Op::SliceCols { c0, c1 } => {
            if needs(0) {
                let (m, n) = {
                    let x = input(0);
                    (x.rows(), x.cols())
                };
                let dst = lower[idx(0)].get_or_insert_with(|| Tensor::zeros(&[m, n]));
                debug_assert_eq!(dst.shape(), &[m, n]);
                for i in 0..m {
                    for (d, &gv) in dst.row_mut(i)[*c0..*c1].iter_mut().zip(g.row(i)) {
                        *d += gv;
                    }
                }
            }
        }
        Op::Row { r } => {
            if needs(0) {
                let (m, n) = {
                    let x = input(0);
                    (x.rows(), x.cols())
                };
                let dst = lower[idx(0)].get_or_insert_with(|| Tensor::zeros(&[m, n]));
                debug_assert_eq!(dst.shape(), &[m, n]);
                for (d, &gv) in dst.row_mut(*r).iter_mut().zip(g.row(0)) {
                    *d += gv;
                }
            }
        }
        Op::ConcatRows => {
            let mut r0 = 0usize;
            for k in 0..node.inputs.len() {
                let rows = input(k).rows();
                if needs(k) {
                    let cols = g.cols();
                    let part = Tensor::new(
                        &[rows, cols],
                        g.data()[r0 * cols..(r0 + rows) * cols].to_vec(),
                    )
                    .unwrap();
                    acc(&mut lower[idx(k)], part);
                }
                r0 += rows;
            }
        }
        Op::StackRows => {
            if needs(0) {
                let xshape = input(0).shape().to_vec();
                acc(&mut lower[idx(0)], g.clone().reshape(&xshape).unwrap());
            }
        }
        Op::LogSoftmax => {
            if needs(0) {
                // dx = g − softmax(x) · rowsum(g), softmax(x) = exp(y)
                let mut dx = g.clone();
                let cols = dx.cols();
                for (drow, yrow) in
                    dx.data_mut().chunks_mut(cols).zip(node.value.data().chunks(cols))
                {
                    let rowsum: f32 = drow.iter().sum();
                    for (d, &y) in drow.iter_mut().zip(yrow) {
                        *d -= y.exp() * rowsum;
                    }
                }
                acc(&mut lower[idx(0)], dx);
            }
        }
        Op::Sum => {
            if needs(0) {
                let gs = g.data()[0];
                acc(&mut lower[idx(0)], Tensor::full(input(0).shape(), gs));
            }
        }
        Op::Ctc { grad } => {
            if needs(0) {
                let mut dx = grad.clone();
                dx.scale(g.data()[0]);
                acc(&mut lower[idx(0)], dx);
            }
        }
        Op::FakeQuant => {
            // straight-through estimator: d(fake_quant(x))/dx ≈ I
            if needs(0) {
                acc(&mut lower[idx(0)], g.clone());
            }
        }
    }
}

//! Reverse-mode tape: the `Var`/`Tape` core of the native trainer.
//!
//! A [`Tape`] is an append-only list of nodes in topological order: leaves
//! (weights, inputs, constants) followed by ops whose inputs are earlier
//! vars.  Forward values are computed eagerly at `push` time and stored on
//! the node, so [`Tape::backward`] is a single reverse sweep that
//! accumulates gradients into a parallel `Vec<Option<Tensor>>` — no graph
//! search, no recursion, no interior mutability.
//!
//! The op set (see [`super::ops`]) is exactly what the factored GRU stack
//! + CTC head of `infer.rs` needs; everything is rank-2 (or rank-1 for
//! biases, rank-0 for the loss).  Gradients only flow into vars whose
//! `requires_grad` flag is set (leaves marked trainable, and any op with
//! at least one trainable ancestor), so constant inputs like the feature
//! matrix and the initial hidden state cost nothing in the backward pass.

use crate::tensor::Tensor;

use super::ops::Op;

/// Handle to a tape node (an index into the tape's node list).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Var(pub(crate) usize);

impl Var {
    /// Position on the tape — the index of this var's gradient slot in
    /// the vector [`Tape::backward`] returns.
    pub fn index(self) -> usize {
        self.0
    }
}

pub(crate) struct Node {
    pub(crate) op: Op,
    pub(crate) inputs: Vec<Var>,
    pub(crate) value: Tensor,
    pub(crate) requires_grad: bool,
}

/// Append-only reverse-mode tape.
#[derive(Default)]
pub struct Tape {
    pub(crate) nodes: Vec<Node>,
}

impl Tape {
    pub fn new() -> Tape {
        Tape::default()
    }

    /// Install a leaf holding `value`; `trainable` marks it as a gradient
    /// sink (weights) vs a constant (inputs, initial hidden state).
    pub fn leaf(&mut self, value: Tensor, trainable: bool) -> Var {
        self.nodes.push(Node {
            op: Op::Leaf,
            inputs: Vec::new(),
            value,
            requires_grad: trainable,
        });
        Var(self.nodes.len() - 1)
    }

    /// Forward value of a var.
    pub fn value(&self, v: Var) -> &Tensor {
        &self.nodes[v.0].value
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Append an op node whose forward `value` has already been computed
    /// by the caller (the op constructors in [`super::ops`]).
    pub(crate) fn push(&mut self, op: Op, inputs: Vec<Var>, value: Tensor) -> Var {
        let requires_grad = inputs.iter().any(|v| self.nodes[v.0].requires_grad);
        self.nodes.push(Node { op, inputs, value, requires_grad });
        Var(self.nodes.len() - 1)
    }

    /// Reverse sweep from the scalar `loss` var: returns one gradient slot
    /// per tape node (`None` where no gradient flowed).  Gradients for a
    /// leaf `v` are at index `v.0`.
    pub fn backward(&self, loss: Var) -> Vec<Option<Tensor>> {
        let n = self.nodes.len();
        let mut grads: Vec<Option<Tensor>> = Vec::with_capacity(n);
        grads.resize_with(n, || None);
        let lshape = self.nodes[loss.0].value.shape().to_vec();
        debug_assert_eq!(
            self.nodes[loss.0].value.len(),
            1,
            "backward seed must be scalar, got {lshape:?}"
        );
        grads[loss.0] = Some(Tensor::full(&lshape, 1.0));
        for i in (0..=loss.0).rev() {
            if !self.nodes[i].requires_grad || matches!(self.nodes[i].op, Op::Leaf) {
                continue;
            }
            // Inputs are strictly earlier on the tape, so splitting at i
            // gives disjoint views: the node's own gradient (read) and
            // every input slot (write).
            let (lower, upper) = grads.split_at_mut(i);
            let Some(g) = upper[0].as_ref() else { continue };
            let node = &self.nodes[i];
            super::ops::backward_op(self, node, g, lower);
        }
        grads
    }
}

/// Accumulate `delta` into an optional gradient slot.
pub(crate) fn acc(slot: &mut Option<Tensor>, delta: Tensor) {
    match slot {
        Some(g) => g
            .add_assign(&delta)
            .expect("gradient shape mismatch (tape op backward bug)"),
        None => *slot = Some(delta),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leaf_and_value() {
        let mut t = Tape::new();
        let v = t.leaf(Tensor::from_vec(vec![1.0, 2.0]), true);
        assert_eq!(t.value(v).data(), &[1.0, 2.0]);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn constant_leaves_get_no_grad() {
        let mut t = Tape::new();
        let a = t.leaf(Tensor::from_vec(vec![1.0, 2.0]), true);
        let b = t.leaf(Tensor::from_vec(vec![3.0, 4.0]), false);
        let y = t.mul(a, b);
        let s = t.sum(y);
        let g = t.backward(s);
        assert!(g[a.0].is_some());
        assert!(g[b.0].is_none(), "constant leaf must not accumulate grad");
        assert_eq!(g[a.0].as_ref().unwrap().data(), &[3.0, 4.0]);
    }
}

//! Native reverse-mode training subsystem (DESIGN.md §2.5) — the L2.5
//! layer that lets the paper's two-stage trace-norm scheme run in the
//! **default offline build**, with no XLA toolchain:
//!
//! * [`tape`] — the `Var`/`Tape` reverse-mode engine: eager forward,
//!   single reverse sweep, gradients only where they are needed.
//! * [`ops`] — the op set of the factored GRU stack (x·Wᵀ GEMM, gate
//!   math, slicing, log-softmax) with textbook adjoints.
//! * [`ctc`] — numerically-stable CTC loss: log-space alpha/beta
//!   recursions on f64, gradient cached at forward time.
//! * [`gru`] — the forward-graph builder mirroring `infer.rs`'s layer
//!   map op for op, so anything trainable here is servable there.
//! * [`optim`] — the trace-norm surrogate penalty (+ analytic gradient),
//!   global-norm clipping, and SGD with momentum.
//!
//! The trainer orchestration on top of these — `NativeTrainer`, the
//! `TrainBackend` trait it shares with the XLA-AOT path, and the native
//! two-stage pipeline — lives in [`crate::train`].  Gradient
//! correctness is enforced by finite-difference property tests in
//! `rust/tests/autograd.rs` (every op, the GRU cell chain, CTC, and the
//! surrogate penalty).

pub mod ctc;
pub mod gru;
pub mod ops;
pub mod optim;
pub mod tape;

pub use ctc::ctc_loss_grad;
pub use gru::{
    batch_ctc_grads, batch_ctc_grads_qat, build_forward, build_forward_qat, utterance_grads,
    utterance_grads_qat, Forward,
};
pub use ops::log_softmax_rows;
pub use optim::{clip_grads, grad_norm, sgd_momentum_step, surrogate_penalty, NativeOpts};
pub use tape::{Tape, Var};

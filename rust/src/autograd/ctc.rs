//! Numerically-stable CTC loss: log-space alpha/beta recursions and the
//! exact input gradient (Graves et al., 2006; the loss the paper trains
//! under, §2).
//!
//! Layout: the label sequence `l` (blanks excluded) is extended to
//! `l' = [∅, l₁, ∅, l₂, …, ∅]` of length `S = 2U + 1` with the blank `∅`
//! at every even position.  Both recursions run entirely in log space on
//! `f64` accumulators (the inputs are f32 log-probs; promoting the
//! lattice avoids the catastrophic underflow a prob-space forward-backward
//! hits past a few dozen frames), using the same `logaddexp` the beam
//! decoder uses ([`crate::decoder::logaddexp`]).
//!
//! Conventions: `alpha[t][s]` and `beta[t][s]` both *include* the
//! emission at `t`, so the path-through-(t,s) mass is
//! `gamma[t][s] = alpha[t][s] + beta[t][s] − logp[t][l'ₛ]` and the
//! gradient of the loss `L = −log P(l|x)` with respect to the log-prob
//! *inputs* (not logits) is
//!
//! ```text
//! ∂L/∂logp[t][k] = −Σ_{s : l'ₛ = k} exp(gamma[t][s] − log P)
//! ```
//!
//! which row-sums to −1 for every `t`; composed with the log-softmax
//! backward this yields the familiar `softmax − occupancy` logits
//! gradient.  The gradient is computed here at forward time (alpha and
//! beta are both in hand) and cached on the tape node ([`super::ops`]).

use crate::decoder::{logaddexp, BLANK};
use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// CTC negative log-likelihood of `logp` (T, V) log-prob rows against
/// `labels` (values in `1..V`; [`BLANK`] = 0 must not appear), plus the
/// gradient ∂loss/∂logp.
///
/// Errors when a label is out of range or when `T` is too short to emit
/// the sequence (`T < U + repeats`, the CTC feasibility bound the
/// synthetic corpus guarantees at its frontend stride — `data.rs`).
pub fn ctc_loss_grad(logp: &Tensor, labels: &[i32]) -> Result<(f32, Tensor)> {
    let (t_len, vocab) = (logp.rows(), logp.cols());
    if t_len == 0 {
        return Err(Error::Train("ctc: empty log-prob matrix".into()));
    }
    for &l in labels {
        if l <= BLANK || l as usize >= vocab {
            return Err(Error::Train(format!(
                "ctc: label {l} outside 1..{vocab} (blank = {BLANK} is implicit)"
            )));
        }
    }
    let u = labels.len();
    let repeats = labels.windows(2).filter(|w| w[0] == w[1]).count();
    if t_len < u + repeats {
        return Err(Error::Train(format!(
            "ctc: {t_len} frames cannot emit {u} labels with {repeats} repeats"
        )));
    }

    // Extended sequence l' = [∅, l1, ∅, l2, ..., ∅].
    let s_len = 2 * u + 1;
    let lab = |s: usize| -> usize {
        if s % 2 == 0 {
            BLANK as usize
        } else {
            labels[s / 2] as usize
        }
    };
    // Skip transition s-2 → s is allowed iff l'_s is a (new) non-blank.
    let can_skip = |s: usize| -> bool { s % 2 == 1 && (s < 2 || labels[s / 2] != labels[s / 2 - 1]) };
    let lp = |t: usize, s: usize| -> f64 { logp.row(t)[lab(s)] as f64 };
    const NEG_INF: f64 = f64::NEG_INFINITY;

    // -- alpha (forward), emission at t included -------------------------
    let mut alpha = vec![NEG_INF; t_len * s_len];
    alpha[0] = lp(0, 0);
    if s_len > 1 {
        alpha[1] = lp(0, 1);
    }
    for t in 1..t_len {
        // paths can end at most 2(t+1) extended positions in, and must
        // leave room to finish: s >= S - 2(T - t)
        let lo = s_len.saturating_sub(2 * (t_len - t));
        let hi = (2 * (t + 1)).min(s_len);
        for s in lo..hi {
            let mut a = alpha[(t - 1) * s_len + s];
            if s >= 1 {
                a = logaddexp(a, alpha[(t - 1) * s_len + s - 1]);
            }
            if s >= 2 && can_skip(s) {
                a = logaddexp(a, alpha[(t - 1) * s_len + s - 2]);
            }
            alpha[t * s_len + s] = if a == NEG_INF { NEG_INF } else { a + lp(t, s) };
        }
    }
    let log_p = if s_len > 1 {
        logaddexp(
            alpha[(t_len - 1) * s_len + s_len - 1],
            alpha[(t_len - 1) * s_len + s_len - 2],
        )
    } else {
        alpha[(t_len - 1) * s_len]
    };
    if log_p == NEG_INF {
        return Err(Error::Train("ctc: no feasible alignment (all paths -inf)".into()));
    }

    // -- beta (backward), emission at t included -------------------------
    let mut beta = vec![NEG_INF; t_len * s_len];
    beta[(t_len - 1) * s_len + s_len - 1] = lp(t_len - 1, s_len - 1);
    if s_len > 1 {
        beta[(t_len - 1) * s_len + s_len - 2] = lp(t_len - 1, s_len - 2);
    }
    for t in (0..t_len - 1).rev() {
        let lo = s_len.saturating_sub(2 * (t_len - t));
        let hi = (2 * (t + 1)).min(s_len);
        for s in lo..hi {
            let mut b = beta[(t + 1) * s_len + s];
            if s + 1 < s_len {
                b = logaddexp(b, beta[(t + 1) * s_len + s + 1]);
            }
            // the skip rule mirrors alpha's: entering s+2 from s skips
            // the blank at s+1, allowed iff l'_{s+2} is a new non-blank
            if s + 2 < s_len && can_skip(s + 2) {
                b = logaddexp(b, beta[(t + 1) * s_len + s + 2]);
            }
            beta[t * s_len + s] = if b == NEG_INF { NEG_INF } else { b + lp(t, s) };
        }
    }

    // -- gradient wrt the log-prob inputs --------------------------------
    let mut grad = Tensor::zeros(&[t_len, vocab]);
    for t in 0..t_len {
        let grow = grad.row_mut(t);
        for s in 0..s_len {
            let (a, b) = (alpha[t * s_len + s], beta[t * s_len + s]);
            if a == NEG_INF || b == NEG_INF {
                continue;
            }
            let gamma = a + b - lp(t, s);
            grow[lab(s)] -= (gamma - log_p).exp() as f32;
        }
    }
    Ok(((-log_p) as f32, grad))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row_normalize(mut logits: Tensor) -> Tensor {
        crate::autograd::ops::log_softmax_rows(&mut logits);
        logits
    }

    #[test]
    fn single_frame_single_label() {
        // T=1, l=[1]: P = p(1), loss = -logp[0][1], grad -1 there only
        let logp = row_normalize(Tensor::new(&[1, 3], vec![0.3, 1.2, -0.5]).unwrap());
        let (loss, grad) = ctc_loss_grad(&logp, &[1]).unwrap();
        assert!((loss + logp.row(0)[1]).abs() < 1e-5);
        assert!((grad.row(0)[1] + 1.0).abs() < 1e-5);
        assert!(grad.row(0)[0].abs() < 1e-6 && grad.row(0)[2].abs() < 1e-6);
    }

    #[test]
    fn two_frames_one_label_matches_hand_sum() {
        // T=2, l=[1]: P = p1(1)p2(1) + p1(0)p2(1) + p1(1)p2(0)
        let logp = row_normalize(Tensor::new(&[2, 3], vec![0.1, 0.9, -0.2, 0.4, -0.3, 0.8]).unwrap());
        let p = |t: usize, k: usize| (logp.row(t)[k] as f64).exp();
        let want = p(0, 1) * p(1, 1) + p(0, 0) * p(1, 1) + p(0, 1) * p(1, 0);
        let (loss, grad) = ctc_loss_grad(&logp, &[1]).unwrap();
        assert!(((loss as f64) + want.ln()).abs() < 1e-5, "{loss} vs {}", -want.ln());
        // every frame's gradient row sums to -1
        for t in 0..2 {
            let s: f32 = grad.row(t).iter().sum();
            assert!((s + 1.0).abs() < 1e-4, "row {t} sums to {s}");
        }
    }

    #[test]
    fn repeated_label_needs_interposed_blank() {
        // l=[1,1] needs T >= 3 (one blank between); T=2 must error
        let logp = row_normalize(Tensor::new(&[2, 3], vec![0.0; 6]).unwrap());
        assert!(ctc_loss_grad(&logp, &[1, 1]).is_err());
        let logp3 = row_normalize(Tensor::new(&[3, 3], vec![0.1; 9]).unwrap());
        let (loss, _) = ctc_loss_grad(&logp3, &[1, 1]).unwrap();
        // only path: 1, blank, 1 → loss = -3·log(1/3)
        assert!(((loss as f64) - 3.0 * (3.0f64).ln()).abs() < 1e-4, "loss {loss}");
    }

    #[test]
    fn rejects_bad_labels() {
        let logp = row_normalize(Tensor::new(&[2, 3], vec![0.0; 6]).unwrap());
        assert!(ctc_loss_grad(&logp, &[0]).is_err(), "blank label");
        assert!(ctc_loss_grad(&logp, &[3]).is_err(), "out of vocab");
    }

    #[test]
    fn empty_label_sequence_is_all_blanks() {
        let logp = row_normalize(Tensor::new(&[3, 2], vec![0.5, -0.1, 0.2, 0.4, -0.3, 0.1]).unwrap());
        let (loss, grad) = ctc_loss_grad(&logp, &[]).unwrap();
        let want: f32 = (0..3).map(|t| logp.row(t)[0]).sum();
        assert!((loss + want).abs() < 1e-5);
        for t in 0..3 {
            assert!((grad.row(t)[0] + 1.0).abs() < 1e-5);
            assert!(grad.row(t)[1].abs() < 1e-6);
        }
    }
}

//! Optimizer + regularizer for the native trainer: the paper's §3
//! trace-norm surrogate penalty with its (analytic) gradient, global
//! gradient-norm clipping, and SGD with classical momentum driving the
//! §3.2.3 LR schedule (the per-epoch decay itself lives in the epoch
//! runner — `train.rs`).
//!
//! The surrogate is Lemma 1's variational bound: for a factored group
//! `W = U·V`,
//!
//! ```text
//! ‖W‖_* ≤ ½(‖U‖²_F + ‖V‖²_F)        (equality at the balanced split)
//! ```
//!
//! so stage 1 penalizes `λ/2·(‖U‖²_F + ‖V‖²_F)` per group — λ_rec on
//! recurrent groups (`rec*`, `grujoint*`), λ_nonrec on the rest — whose
//! gradient is simply `λU` / `λV`.  Dense (unfactored) groups fall back
//! to the paper's ℓ² baseline `λ/2·‖W‖²_F` with gradient `λW`.  Conv and
//! the output projection are never regularized (§3.2), matching
//! [`crate::model::group_bases`].

use crate::error::Result;
use crate::linalg;
use crate::model::{self, ParamSet};

/// Native-optimizer knobs, orthogonal to the schedule in
/// [`crate::train::TrainOpts`].
#[derive(Clone, Copy, Debug)]
pub struct NativeOpts {
    /// classical momentum coefficient μ
    pub momentum: f32,
    /// global gradient-norm ceiling; 0 disables clipping
    pub clip: f32,
    /// quantization-aware fine-tuning: wrap every weight matrix in a
    /// straight-through `fake_quant` node at the given width (4 or 8)
    /// so the forward pass sees inference-time rounding.  `None`
    /// trains in plain f32 (stage 1 always clears this).
    pub qat_bits: Option<u32>,
}

impl Default for NativeOpts {
    fn default() -> Self {
        NativeOpts { momentum: 0.9, clip: 2.0, qat_bits: None }
    }
}

/// Trace-norm surrogate penalty and its gradient over every compressible
/// group: returns `(penalty value, gradient ParamSet holding only the
/// group factors/weights)`.
pub fn surrogate_penalty(
    params: &ParamSet,
    lam_rec: f32,
    lam_nonrec: f32,
) -> Result<(f32, ParamSet)> {
    let mut penalty = 0.0f32;
    let mut grads = ParamSet::new();
    for base in model::group_bases(params) {
        let lam = if model::is_recurrent_group(&base) { lam_rec } else { lam_nonrec };
        if lam == 0.0 {
            continue;
        }
        if params.contains(&format!("{base}_u")) {
            let u = params.get(&format!("{base}_u"))?;
            let v = params.get(&format!("{base}_v"))?;
            penalty += lam * linalg::surrogate_norm(u, v);
            let mut gu = u.clone();
            gu.scale(lam);
            let mut gv = v.clone();
            gv.scale(lam);
            grads.set(format!("{base}_u"), gu);
            grads.set(format!("{base}_v"), gv);
        } else {
            let w = params.get(&format!("{base}_w"))?;
            penalty += 0.5 * lam * w.data().iter().map(|x| x * x).sum::<f32>();
            let mut gw = w.clone();
            gw.scale(lam);
            grads.set(format!("{base}_w"), gw);
        }
    }
    Ok((penalty, grads))
}

/// Global L2 norm across all gradient tensors.
pub fn grad_norm(grads: &ParamSet) -> f32 {
    grads
        .iter()
        .map(|(_, g)| g.data().iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>())
        .sum::<f64>()
        .sqrt() as f32
}

/// Clip gradients to a global-norm ceiling in place; returns the
/// **pre-clip** norm (the `grad_norm` metric).  `max_norm <= 0` disables.
pub fn clip_grads(grads: &mut ParamSet, max_norm: f32) -> f32 {
    let norm = grad_norm(grads);
    if max_norm > 0.0 && norm > max_norm {
        let s = max_norm / norm;
        for (_, g) in grads.iter_mut() {
            g.scale(s);
        }
    }
    norm
}

/// One SGD-with-momentum update:
/// `v ← μ·v + g`, `w ← w − lr·v` for every parameter.
pub fn sgd_momentum_step(
    params: &mut ParamSet,
    velocity: &mut ParamSet,
    grads: &ParamSet,
    lr: f32,
    mu: f32,
) -> Result<()> {
    for (name, g) in grads.iter() {
        let v = velocity.get_mut(name)?;
        for (vi, gi) in v.data_mut().iter_mut().zip(g.data()) {
            *vi = mu * *vi + gi;
        }
        let w = params.get_mut(name)?;
        for (wi, vi) in w.data_mut().iter_mut().zip(velocity.get(name)?.data()) {
            *wi -= lr * vi;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;
    use crate::tensor::Tensor;

    #[test]
    fn penalty_matches_frobenius_sums_and_grad_is_lambda_w() {
        let mut rng = Pcg64::seeded(1);
        let mut p = ParamSet::new();
        let u = Tensor::randn(&[6, 3], 1.0, &mut rng);
        let v = Tensor::randn(&[3, 4], 1.0, &mut rng);
        p.set("rec0_u", u.clone());
        p.set("rec0_v", v.clone());
        p.set("fc_w", Tensor::randn(&[4, 4], 1.0, &mut rng));
        p.set("out_w", Tensor::randn(&[2, 4], 1.0, &mut rng)); // not a group

        let (pen, grads) = surrogate_penalty(&p, 0.5, 0.0).unwrap();
        let want = 0.5 * 0.5 * (u.frob_norm().powi(2) + v.frob_norm().powi(2));
        assert!((pen - want).abs() < 1e-4, "{pen} vs {want}");
        // λ_nonrec = 0 → fc untouched; out never regularized
        assert!(!grads.contains("fc_w") && !grads.contains("out_w"));
        let gu = grads.get("rec0_u").unwrap();
        for (g, w) in gu.data().iter().zip(u.data()) {
            assert!((g - 0.5 * w).abs() < 1e-6);
        }
    }

    #[test]
    fn clip_rescales_to_ceiling() {
        let mut g = ParamSet::new();
        g.set("a_w", Tensor::new(&[1, 2], vec![3.0, 4.0]).unwrap());
        let pre = clip_grads(&mut g, 1.0);
        assert!((pre - 5.0).abs() < 1e-5);
        let post = grad_norm(&g);
        assert!((post - 1.0).abs() < 1e-5);
        // disabled clip leaves gradients alone
        let mut g2 = ParamSet::new();
        g2.set("a_w", Tensor::new(&[1, 2], vec![3.0, 4.0]).unwrap());
        assert!((clip_grads(&mut g2, 0.0) - 5.0).abs() < 1e-5);
        assert!((grad_norm(&g2) - 5.0).abs() < 1e-5);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut p = ParamSet::new();
        p.set("w", Tensor::scalar(1.0));
        let mut vel = ParamSet::zeros_like(&p);
        let mut g = ParamSet::new();
        g.set("w", Tensor::scalar(1.0));
        sgd_momentum_step(&mut p, &mut vel, &g, 0.1, 0.5).unwrap();
        // v = 1, w = 1 - 0.1
        assert!((p.get("w").unwrap().data()[0] - 0.9).abs() < 1e-6);
        sgd_momentum_step(&mut p, &mut vel, &g, 0.1, 0.5).unwrap();
        // v = 0.5 + 1 = 1.5, w = 0.9 - 0.15
        assert!((p.get("w").unwrap().data()[0] - 0.75).abs() < 1e-6);
    }
}

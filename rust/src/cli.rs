//! Hand-rolled CLI (no clap in the offline environment).
//!
//! ```text
//! repro <subcommand> [--key value]...
//!
//! subcommands:
//!   info                         list artifacts + configs from the manifest
//!   experiment <id|all>          regenerate a paper table/figure (fig1..fig8,
//!                                table1..table3)
//!   train                        single training run (XLA-AOT artifacts)
//!                                  --artifact train_mini_partial_full
//!                                  --epochs 5 --lr 0.003
//!                                  --lam-rec 0 --lam-nonrec 0
//!   train --native               pure-Rust autograd + CTC training in the
//!                                default offline build (DESIGN.md §2.5):
//!                                the full §3 two-stage scheme by default
//!                                  --stage two|1|2 --epochs N --transition N
//!                                  --lr F --momentum F --clip F
//!                                  --lam-rec F --lam-nonrec F --threshold F
//!                                  --utts N --dev-utts N --batch N --seed N
//!                                  --bits 4|8 (quantization-aware fine-tune:
//!                                the forward pass trains through the serving
//!                                quantizer via a straight-through estimator;
//!                                stage 1 always stays f32)
//!                                  --save CKPT (TNCK-v2 train-state: params
//!                                  + momentum + LR-schedule meta)
//!                                  --load CKPT (resume a train-state, or
//!                                  warmstart stage 2 from stage-1 params)
//!   two-stage                    full §3 pipeline
//!                                  --stage1 train_mini_partial_full
//!                                  --family train_mini_partial
//!                                  --threshold 0.9 --transition 3 --total 8
//!   transcribe                   train briefly, then transcribe test
//!                                utterances with the embedded engine
//!                                  --precision int8|f32 --bits 8|4
//!                                  --backend scalar|blocked|simd|auto
//!                                  --autotune on|off --fused-gates on|off
//!   bench-gemm                   quick farm-vs-lowp timing sweep
//!   stream-serve                 multi-stream serving demo: Poisson
//!                                arrivals over concurrent decode sessions,
//!                                sharded across worker threads
//!                                  --pool 4 --rate 8 --utts 32 --chunk 16
//!                                  --shards N (worker shards; default 1 —
//!                                  bit-identical to the unsharded path)
//!                                  --json (machine-readable report)
//!                                  --precision int8|f32 [--load ckpt]
//!                                  --bits 8|4 (quantized-weight width:
//!                                8 is the int8 path, 4 the packed sub-byte
//!                                nibble path with per-group scales —
//!                                DESIGN.md §4)
//!                                  --backend scalar|blocked|simd|auto
//!                                (the GEMM backend; simd needs the `simd`
//!                                cargo feature — DESIGN.md §4)
//!                                  --autotune on|off (construction-time
//!                                NR/KC tile probing for the blocked packed
//!                                layout; off pins the defaults)
//!                                  --fused-gates on|off (route the
//!                                recurrent GEMM through the fused GRU-gate
//!                                kernel; bit-identical either way)
//!                                  --obs on|off (flight-recorder spans,
//!                                kernel counters and the shard event
//!                                journal — off by default, bit-identical
//!                                transcripts either way; DESIGN.md §10)
//!                                  --metrics-out FILE (JSONL snapshot
//!                                stream; also accepted by train --native
//!                                for per-epoch snapshots)
//!                                  --trace-out FILE (Chrome-trace /
//!                                Perfetto JSON of per-session causal
//!                                traces; needs --obs on)
//!                                  --slo-target MS --slo-budget FRAC
//!                                (declarative p99/availability SLO with
//!                                multi-window burn-rate alerts)
//!                                  --slo-actions on|off (off by default:
//!                                observe only; on lets a breach shed
//!                                admissions / pressure the controllers)
//!                                  --fixed-tick-ms F (deterministic
//!                                simulated clock: byte-identical traces)
//!                                  --cascade LOWFRAC:HIGHFRAC
//!                                (confidence-gated cascade over two
//!                                synthetic rank fractions: blocks decode
//!                                on the cheap LOW rung and re-run on HIGH
//!                                only when worst-frame confidence breaches
//!                                the threshold — DESIGN.md §11)
//!                                  --escalate-threshold T (0 is bit-
//!                                identical to pure LOW, inf to pure HIGH)
//!                                with --ladder DIR: adaptive-fidelity
//!                                serving over a built rank ladder, with a
//!                                synthetic load ramp, per-shard fidelity
//!                                controllers and a per-tier report
//!                                  --ladder DIR --ramp-utts N --ramp-rate F
//!                                  --target-p99-ms F
//!                                  --cascade LOW:HIGH (rung tags like
//!                                r0250:r0750 or tier indices; sessions on
//!                                the LOW tier escalate breached blocks to
//!                                HIGH, and the fidelity controllers steer
//!                                the threshold under SLO pressure before
//!                                shifting admission tiers)
//!   ladder-build                 offline rank-ladder build: truncated SVD
//!                                per group at each rank fraction, int8 or
//!                                packed-int4 quantization (--bits), one
//!                                TNCK-v2 artifact per rung + ladder.json
//!                                  --out DIR --fracs 0.75,0.5,0.25
//!                                  --bits 8|4 [--load ckpt]
//!   obs-report FILE.jsonl        offline analyzer over a --metrics-out
//!                                capture: envelope validation, replayed
//!                                per-session timelines, self-time trend,
//!                                per-tier SLO attainment/burn tables
//!                                  [--slo-target MS] [--slo-budget FRAC]
//!                                  [--trace-out FILE] (re-emit the
//!                                Perfetto trace from the JSONL alone)
//! ```
//!
//! Every flag becomes a config key (`--lam-rec 0.1` → `cli.lam-rec`), and
//! experiment knobs may be set the same way (`--exp.epochs1 3`).

use crate::configx::Config;
use crate::error::{Error, Result};

/// A parsed invocation.
#[derive(Clone, Debug)]
pub struct Cli {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub cfg: Config,
}

pub const USAGE: &str = "usage: repro <info|experiment|train|two-stage|transcribe|bench-gemm|stream-serve|ladder-build|obs-report> [args]
  repro info                      list artifacts + configs from the manifest
  repro experiment <fig1|fig2|fig3|fig4|fig5|fig6|fig7|fig8|table1|table2|table3|all>
  repro train --artifact <name> [--epochs N] [--lr F] [--lam-rec F] [--lam-nonrec F]
              [--load CKPT] [--save CKPT]
  repro train --native [--stage two|1|2] [--epochs N] [--transition N] [--lr F]
              [--momentum F] [--clip F] [--lam-rec F] [--lam-nonrec F] [--threshold T]
              [--utts N] [--dev-utts N] [--batch N] [--seed N] [--load CKPT] [--save CKPT]
              [--bits 4|8] [--metrics-out FILE]
              (offline two-stage trace-norm training, no XLA; saves a TNCK-v2
               train-state that ladder-build / stream-serve --load serve directly;
               --bits fine-tunes through the int4/int8 serving quantizer — a
               straight-through estimator; stage 1 always trains plain f32;
               --metrics-out writes one versioned JSONL snapshot per epoch)
  repro two-stage [--stage1 A] [--family F] [--threshold T] [--transition E] [--total E]
  repro transcribe [--precision int8|f32] [--bits 8|4] [--utts N]
                   [--backend scalar|blocked|simd|auto]
                   [--autotune on|off] [--fused-gates on|off]
  repro bench-gemm [--reps N]
  repro stream-serve [--shards N] [--pool N] [--rate F] [--utts N] [--chunk N] [--json]
                     [--precision int8|f32] [--bits 8|4] [--rank-frac F] [--time-batch N]
                     [--scheme S] [--load CKPT] [--seed N]
                     [--backend scalar|blocked|simd|auto]
                     [--autotune on|off] [--fused-gates on|off] [--obs on|off]
                     [--metrics-out FILE] [--trace-out FILE] [--fixed-tick-ms F]
                     [--slo-target MS] [--slo-budget FRAC] [--slo-actions on|off]
                     [--cascade LOWFRAC:HIGHFRAC] [--escalate-threshold T]
                     (--shards N spreads sessions over N worker threads; --shards 1,
                      the default, is bit-identical to the unsharded serving path;
                      --bits 4 serves packed sub-byte weights — int4 nibbles with
                      per-group scales, bit-identical across backends;
                      --autotune off pins the default NR/KC packing tiles;
                      --fused-gates off pins the plain stacked recurrent sweep —
                      decoding is bit-identical on or off;
                      --obs on records stage spans, kernel counters and the shard
                      event journal into the report, --metrics-out streams periodic
                      JSONL snapshots — transcripts are bit-identical either way;
                      --trace-out writes a Chrome-trace/Perfetto JSON of per-session
                      causal traces (needs --obs on); --fixed-tick-ms F advances the
                      simulated clock by exactly F ms per round, making the trace
                      byte-identical run to run;
                      --slo-target declares a p99/availability SLO evaluated with
                      multi-window burn-rate alerts; --slo-actions on (default off)
                      lets a breach shed admissions / pressure the controllers;
                      --cascade LOWFRAC:HIGHFRAC decodes every block on the cheap
                      LOW rank fraction and re-runs only low-confidence blocks on
                      HIGH from a block-boundary checkpoint — --escalate-threshold 0
                      is bit-identical to pure LOW, inf to pure HIGH)
  repro stream-serve --ladder DIR [--shards N] [--pool N] [--utts N] [--chunk N] [--rate F]
                     [--ramp-utts N] [--ramp-rate F] [--target-p99-ms F] [--seed N] [--json]
                     [--backend scalar|blocked|simd|auto] [--autotune on|off]
                     [--fused-gates on|off] [--obs on|off] [--metrics-out FILE]
                     [--trace-out FILE] [--fixed-tick-ms F] [--slo-target MS]
                     [--slo-budget FRAC] [--slo-actions on|off]
                     [--cascade LOW:HIGH] [--escalate-threshold T]
                     (adaptive-fidelity serving over a built rank ladder; per-shard
                      fidelity controllers with a merged, shard-tagged shift log;
                      --cascade names two rungs by tag or tier index — LOW-tier
                      sessions escalate low-confidence blocks to the HIGH rung, and
                      controllers cut the threshold under SLO pressure before
                      downshifting admission tiers)
  repro ladder-build --out DIR [--fracs F,F,...] [--bits 8|4] [--load CKPT] [--seed N]
                     (offline SVD-truncate + int8/int4-quantize, one artifact per rung)
  repro obs-report FILE.jsonl [--slo-target MS] [--slo-budget FRAC] [--trace-out FILE]
                     (offline analyzer over a --metrics-out capture: envelope
                      validation, replayed per-session timelines, self-time trend,
                      per-tier SLO attainment/burn tables; --trace-out re-emits the
                      Perfetto trace from the JSONL alone)
common flags: --artifacts DIR --results DIR --seed N --exp.<knob> V";

/// Parse argv (excluding argv[0]).
pub fn parse(args: &[String]) -> Result<Cli> {
    if args.is_empty() {
        return Err(Error::Config(USAGE.into()));
    }
    let subcommand = args[0].clone();
    let mut positional = Vec::new();
    let mut cfg = Config::new();
    let mut i = 1;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            let value = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string() // bare flag
            };
            // flags with dots address config sections directly; plain
            // flags live under their own name
            cfg.set(key, value);
            i += 1;
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    Ok(Cli { subcommand, positional, cfg })
}

impl Cli {
    pub fn flag_f64(&self, name: &str, default: f64) -> f64 {
        self.cfg.f64_or(name, default)
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> usize {
        self.cfg.usize_or(name, default)
    }

    pub fn flag_str(&self, name: &str, default: &str) -> String {
        self.cfg.str_or(name, default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let cli = parse(&s(&["train", "--artifact", "a", "--epochs", "7", "--quiet"])).unwrap();
        assert_eq!(cli.subcommand, "train");
        assert_eq!(cli.flag_str("artifact", ""), "a");
        assert_eq!(cli.flag_usize("epochs", 0), 7);
        assert!(cli.cfg.bool_or("quiet", false));
    }

    #[test]
    fn parses_positional() {
        let cli = parse(&s(&["experiment", "fig1", "--seed", "3"])).unwrap();
        assert_eq!(cli.positional, vec!["fig1"]);
        assert_eq!(cli.flag_usize("seed", 0), 3);
    }

    #[test]
    fn empty_args_error() {
        assert!(parse(&[]).is_err());
    }

    #[test]
    fn dotted_flags_hit_sections() {
        let cli = parse(&s(&["experiment", "all", "--exp.epochs1", "2"])).unwrap();
        assert_eq!(cli.cfg.usize_or("exp.epochs1", 0), 2);
    }
}

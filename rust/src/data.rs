//! Synthetic speech corpus (the WSJ stand-in — see DESIGN.md §3).
//!
//! Every utterance is a short word sequence rendered to mel-like feature
//! frames: each character has a deterministic spectral template (a few
//! active bands), rendered for a random 6–10 frame duration with
//! coarticulation blending at boundaries plus white noise.  The mapping
//! frames→characters is therefore *learnable but non-trivial* (durations
//! vary, boundaries are blurred, noise corrupts), exercising the identical
//! CTC + GEMM training machinery as real filterbanks.
//!
//! Determinism: the corpus is a pure function of (seed, size); train/dev/
//! test splits never overlap utterance seeds.

use crate::prng::Pcg64;
use crate::runtime::{BatchGeom, Value};
use crate::tensor::Tensor;

/// Built-in word list (small vocabulary, letters only — the alphabet also
/// carries space and apostrophe; "don't" exercises the apostrophe).
pub const WORDS: &[&str] = &[
    "the", "and", "cat", "dog", "run", "sun", "sky", "red", "blue", "green",
    "fast", "slow", "big", "small", "one", "two", "ten", "go", "stop", "yes",
    "no", "up", "down", "left", "right", "play", "work", "home", "road", "tree",
    "bird", "fish", "hand", "eye", "ear", "day", "night", "rain", "snow", "wind",
    "don't", "it's", "time", "word", "talk", "ask", "call", "deep", "speech", "model",
];

/// Character alphabet, identical to python configs.ALPHABET:
/// index 0 = CTC blank, 1 = space, 2 = apostrophe, 3.. = 'a'..'z'.
pub fn char_to_index(c: char) -> Option<i32> {
    match c {
        ' ' => Some(1),
        '\'' => Some(2),
        'a'..='z' => Some(3 + (c as u8 - b'a') as i32),
        _ => None,
    }
}

pub fn index_to_char(i: i32) -> Option<char> {
    match i {
        1 => Some(' '),
        2 => Some('\''),
        3..=28 => Some((b'a' + (i - 3) as u8) as char),
        _ => None,
    }
}

pub fn text_to_labels(text: &str) -> Vec<i32> {
    text.chars().filter_map(char_to_index).collect()
}

pub fn labels_to_text(labels: &[i32]) -> String {
    labels.iter().filter_map(|&i| index_to_char(i)).collect()
}

/// Corpus generation parameters.
#[derive(Clone, Debug)]
pub struct CorpusSpec {
    pub seed: u64,
    pub feat_dim: usize,
    pub max_frames: usize,
    pub max_label: usize,
    /// character duration range in frames (inclusive)
    pub dur_min: usize,
    pub dur_max: usize,
    /// white-noise std added to every frame
    pub noise: f32,
    /// number of active spectral bands per character template
    pub bands: usize,
    /// frontend stride the corpus must stay CTC-feasible for: rendered
    /// utterances satisfy frames/stride >= labels + repeats + 1 (repeated
    /// characters need an interposed blank), else they are resampled
    pub feasibility_stride: usize,
}

impl CorpusSpec {
    pub fn standard(seed: u64) -> CorpusSpec {
        // Difficulty is tuned so that a few epochs of the wsj_mini model
        // land in the high-single-digit CER range (the paper's WSJ regime):
        // heavy frame noise + overlapping 3-band templates + duration
        // jitter keep the mapping learnable but leave headroom for the
        // regularization and rank trade-offs to be visible.
        CorpusSpec {
            seed,
            feat_dim: 40,
            max_frames: 128,
            max_label: 12,
            dur_min: 4,
            dur_max: 9,
            noise: 0.55,
            bands: 3,
            feasibility_stride: 4,
        }
    }
}

/// One rendered utterance.
#[derive(Clone, Debug)]
pub struct Utterance {
    pub text: String,
    pub labels: Vec<i32>,
    /// (frames, feat_dim)
    pub feats: Tensor,
}

/// Deterministic per-character spectral template.
fn char_template(spec: &CorpusSpec, c: i32) -> Vec<f32> {
    let mut rng = Pcg64::new(spec.seed ^ 0xc0de, 1000 + c as u64);
    let mut t = vec![-0.5f32; spec.feat_dim];
    for _ in 0..spec.bands {
        let center = rng.below(spec.feat_dim);
        let amp = rng.uniform_in(0.8, 2.0) as f32;
        // triangular band of width 3
        for (off, w) in [(0isize, 1.0f32), (-1, 0.5), (1, 0.5)] {
            let idx = center as isize + off;
            if idx >= 0 && (idx as usize) < spec.feat_dim {
                t[idx as usize] += amp * w;
            }
        }
    }
    t
}

/// Render one utterance from text. Returns None if it would exceed the
/// frame budget.
pub fn render(spec: &CorpusSpec, text: &str, rng: &mut Pcg64) -> Option<Utterance> {
    let labels = text_to_labels(text);
    if labels.is_empty() || labels.len() > spec.max_label {
        return None;
    }
    let mut frames: Vec<Vec<f32>> = Vec::new();
    let mut prev_t: Option<Vec<f32>> = None;
    for &c in &labels {
        let t = char_template(spec, c);
        let dur = spec.dur_min + rng.below(spec.dur_max - spec.dur_min + 1);
        for k in 0..dur {
            let mut f = t.clone();
            // coarticulation: first frame of a char blends with the
            // previous char's template
            if k == 0 {
                if let Some(p) = &prev_t {
                    for (fi, pi) in f.iter_mut().zip(p) {
                        *fi = 0.5 * *fi + 0.5 * pi;
                    }
                }
            }
            for v in f.iter_mut() {
                *v += rng.normal_f32(0.0, spec.noise);
            }
            frames.push(f);
        }
        prev_t = Some(t);
    }
    if frames.len() > spec.max_frames {
        return None;
    }
    // CTC feasibility at the frontend stride (repeated labels need an
    // interposed blank step); infeasible draws are resampled by callers.
    let repeats = labels.windows(2).filter(|w| w[0] == w[1]).count();
    if frames.len() / spec.feasibility_stride < labels.len() + repeats + 1 {
        return None;
    }
    let n = frames.len();
    let data: Vec<f32> = frames.into_iter().flatten().collect();
    Some(Utterance {
        text: text.to_string(),
        labels,
        feats: Tensor::new(&[n, spec.feat_dim], data).ok()?,
    })
}

/// Sample a random utterance text (1–3 words within the label budget).
pub fn sample_text(spec: &CorpusSpec, rng: &mut Pcg64) -> String {
    loop {
        let n_words = 1 + rng.below(3);
        let mut parts = Vec::new();
        for _ in 0..n_words {
            parts.push(WORDS[rng.below(WORDS.len())]);
        }
        let text = parts.join(" ");
        if text.chars().count() <= spec.max_label {
            return text;
        }
    }
}

/// A split dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub spec: CorpusSpec,
    pub train: Vec<Utterance>,
    pub dev: Vec<Utterance>,
    pub test: Vec<Utterance>,
}

impl Dataset {
    /// Generate a corpus of the given split sizes.
    pub fn generate(spec: CorpusSpec, n_train: usize, n_dev: usize, n_test: usize) -> Dataset {
        let mut rng = Pcg64::new(spec.seed, 7);
        let mut make = |n: usize, stream: u64| {
            let mut out = Vec::with_capacity(n);
            let mut r = rng.fork(stream);
            while out.len() < n {
                let text = sample_text(&spec, &mut r);
                if let Some(u) = render(&spec, &text, &mut r) {
                    out.push(u);
                }
            }
            out
        };
        let train = make(n_train, 1);
        let dev = make(n_dev, 2);
        let test = make(n_test, 3);
        Dataset { spec, train, dev, test }
    }

    /// All training transcripts (LM training data).
    pub fn train_texts(&self) -> Vec<&str> {
        self.train.iter().map(|u| u.text.as_str()).collect()
    }
}

/// A padded batch in artifact wire format.
#[derive(Clone, Debug)]
pub struct Batch {
    pub feats: Value,
    pub frame_lens: Value,
    pub labels: Value,
    pub label_lens: Value,
    /// reference texts (for CER)
    pub texts: Vec<String>,
}

impl Batch {
    /// Per-row unpadded views for the native trainer
    /// ([`crate::train::NativeTrainer`]): the i-th row's `(frames_i,
    /// feat_dim)` features and its label sequence.  Pad-replica rows
    /// (see [`make_batch`]) are returned too — the loss averages over
    /// all rows, matching the AOT artifacts' batch semantics.  Rows with
    /// zero frames (an under-filled batch with no utterances to
    /// replicate) are skipped.
    pub fn utterances(&self) -> crate::error::Result<Vec<(Tensor, Vec<i32>)>> {
        let feats = self.feats.as_f32()?;
        let shape = feats.shape();
        if shape.len() != 3 {
            return Err(crate::error::Error::Shape(format!(
                "batch feats must be (b, max_frames, feat), got {shape:?}"
            )));
        }
        let (b, max_t, f) = (shape[0], shape[1], shape[2]);
        let frame_lens = self.frame_lens.as_i32()?;
        let labels = self.labels.as_i32()?;
        let label_lens = self.label_lens.as_i32()?;
        let max_l = self.labels.shape()[1];
        let mut out = Vec::with_capacity(b);
        for i in 0..b {
            let t = (frame_lens[i] as usize).min(max_t);
            if t == 0 {
                continue;
            }
            let data = feats.data()[i * max_t * f..(i * max_t + t) * f].to_vec();
            let l = (label_lens[i] as usize).min(max_l);
            let lab = labels[i * max_l..i * max_l + l].to_vec();
            out.push((Tensor::new(&[t, f], data)?, lab));
        }
        Ok(out)
    }
}

/// Assemble utterances into the static-shape batch an artifact expects.
/// Fewer utterances than `geom.batch` are padded with empty (zero-length)
/// rows whose CTC loss contribution is masked by `label_lens = 0`... the
/// AOT loss averages over batch rows, so callers should fill full batches
/// during training (the batcher below does).
pub fn make_batch(utts: &[&Utterance], geom: &BatchGeom, feat_dim: usize) -> Batch {
    let b = geom.batch;
    let mut feats = Tensor::zeros(&[b, geom.max_frames, feat_dim]);
    let mut frame_lens = vec![0i32; b];
    let mut labels = vec![0i32; b * geom.max_label];
    let mut label_lens = vec![0i32; b];
    let mut texts = Vec::with_capacity(b);
    for (i, u) in utts.iter().take(b).enumerate() {
        let t = u.feats.shape()[0];
        let f = u.feats.shape()[1];
        let dst = feats.data_mut();
        for (ti, row) in u.feats.data().chunks(f).enumerate() {
            let off = (i * geom.max_frames + ti) * feat_dim;
            dst[off..off + f].copy_from_slice(row);
        }
        frame_lens[i] = t as i32;
        for (j, &l) in u.labels.iter().take(geom.max_label).enumerate() {
            labels[i * geom.max_label + j] = l;
        }
        label_lens[i] = u.labels.len().min(geom.max_label) as i32;
        texts.push(u.text.clone());
    }
    // pad rows replicate the last real utterance to keep the loss finite
    for i in utts.len()..b {
        if let Some(u) = utts.last() {
            let t = u.feats.shape()[0];
            let f = u.feats.shape()[1];
            let dst = feats.data_mut();
            for (ti, row) in u.feats.data().chunks(f).enumerate() {
                let off = (i * geom.max_frames + ti) * feat_dim;
                dst[off..off + f].copy_from_slice(row);
            }
            frame_lens[i] = t as i32;
            for (j, &l) in u.labels.iter().take(geom.max_label).enumerate() {
                labels[i * geom.max_label + j] = l;
            }
            label_lens[i] = u.labels.len().min(geom.max_label) as i32;
            texts.push(u.text.clone());
        }
    }
    Batch {
        feats: Value::F32(feats),
        frame_lens: Value::I32(frame_lens, vec![b]),
        labels: Value::I32(labels, vec![b, geom.max_label]),
        label_lens: Value::I32(label_lens, vec![b]),
        texts,
    }
}

/// Epoch batcher: shuffles utterance order each epoch (seeded).
pub struct Batcher<'a> {
    utts: Vec<&'a Utterance>,
    geom: BatchGeom,
    feat_dim: usize,
    rng: Pcg64,
}

impl<'a> Batcher<'a> {
    pub fn new(utts: &'a [Utterance], geom: BatchGeom, feat_dim: usize, seed: u64) -> Self {
        Batcher {
            utts: utts.iter().collect(),
            geom,
            feat_dim,
            rng: Pcg64::seeded(seed),
        }
    }

    /// Number of full batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        self.utts.len() / self.geom.batch
    }

    /// One shuffled epoch of full batches.
    pub fn epoch(&mut self) -> Vec<Batch> {
        self.rng.shuffle(&mut self.utts);
        self.utts
            .chunks(self.geom.batch)
            .filter(|c| c.len() == self.geom.batch)
            .map(|c| make_batch(c, &self.geom, self.feat_dim))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom() -> BatchGeom {
        BatchGeom { batch: 4, max_frames: 128, max_label: 12 }
    }

    #[test]
    fn char_index_roundtrip() {
        for c in "abcz' ".chars() {
            let i = char_to_index(c).unwrap();
            assert_eq!(index_to_char(i), Some(c));
        }
        assert_eq!(char_to_index('!'), None);
        assert_eq!(index_to_char(0), None); // blank is not a character
    }

    #[test]
    fn corpus_is_deterministic() {
        let a = Dataset::generate(CorpusSpec::standard(5), 10, 4, 4);
        let b = Dataset::generate(CorpusSpec::standard(5), 10, 4, 4);
        assert_eq!(a.train[3].text, b.train[3].text);
        assert_eq!(a.train[3].feats, b.train[3].feats);
        let c = Dataset::generate(CorpusSpec::standard(6), 10, 4, 4);
        assert!(a.train.iter().zip(&c.train).any(|(x, y)| x.text != y.text));
    }

    #[test]
    fn utterances_fit_budgets() {
        let d = Dataset::generate(CorpusSpec::standard(1), 50, 10, 10);
        for u in d.train.iter().chain(&d.dev).chain(&d.test) {
            assert!(u.labels.len() <= 12);
            assert!(u.feats.shape()[0] <= 128);
            assert!(u.feats.shape()[0] >= u.labels.len()); // CTC feasibility
            assert_eq!(u.labels, text_to_labels(&u.text));
        }
    }

    #[test]
    fn same_char_renders_similarly_different_chars_differ() {
        let spec = CorpusSpec::standard(2);
        let ta = char_template(&spec, char_to_index('a').unwrap());
        let ta2 = char_template(&spec, char_to_index('a').unwrap());
        let tb = char_template(&spec, char_to_index('b').unwrap());
        assert_eq!(ta, ta2);
        let diff: f32 = ta.iter().zip(&tb).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1.0, "templates too similar: {diff}");
    }

    #[test]
    fn batch_shapes_and_padding() {
        let d = Dataset::generate(CorpusSpec::standard(3), 6, 2, 2);
        let refs: Vec<&Utterance> = d.train.iter().take(4).collect();
        let b = make_batch(&refs, &geom(), 40);
        assert_eq!(b.feats.shape(), vec![4, 128, 40]);
        assert_eq!(b.labels.shape(), vec![4, 12]);
        let lens = b.frame_lens.as_i32().unwrap();
        assert!(lens.iter().all(|&l| l > 0 && l <= 128));
        // padding beyond frame_lens is zero
        let feats = b.feats.as_f32().unwrap();
        let l0 = lens[0] as usize;
        if l0 < 128 {
            let row = &feats.data()[(l0 * 40)..(l0 * 40 + 40)];
            assert!(row.iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn batch_utterances_roundtrip_unpadded() {
        let d = Dataset::generate(CorpusSpec::standard(7), 6, 2, 2);
        let refs: Vec<&Utterance> = d.train.iter().take(3).collect();
        let b = make_batch(&refs, &geom(), 40);
        let utts = b.utterances().unwrap();
        // 3 real rows + 1 pad replica of the last utterance
        assert_eq!(utts.len(), 4);
        for (i, u) in refs.iter().enumerate() {
            assert_eq!(utts[i].0, u.feats, "row {i} feats");
            assert_eq!(utts[i].1, u.labels, "row {i} labels");
        }
        assert_eq!(utts[3].0, refs[2].feats, "pad row replicates the last utterance");
        assert_eq!(utts[3].1, refs[2].labels);
    }

    #[test]
    fn batcher_covers_epoch() {
        let d = Dataset::generate(CorpusSpec::standard(4), 17, 2, 2);
        let mut b = Batcher::new(&d.train, geom(), 40, 0);
        let e = b.epoch();
        assert_eq!(e.len(), 4); // 17 / 4
        let e2 = b.epoch();
        // shuffling changes batch composition across epochs (overwhelmingly)
        let t1: Vec<_> = e.iter().flat_map(|x| x.texts.clone()).collect();
        let t2: Vec<_> = e2.iter().flat_map(|x| x.texts.clone()).collect();
        assert_ne!(t1, t2);
    }
}

//! Checkpointing: save/load [`ParamSet`]s (and whole training states) to a
//! self-describing binary format.
//!
//! Two on-disk versions share the `TNCK` magic and fnv1a trailer:
//!
//! v1 — flat f32 parameter sets (training checkpoints):
//! ```text
//! magic "TNCK" | u32 version=1 | u32 n_entries
//! per entry: u32 name_len | name bytes | u32 rank | u64 dims... | f32 data...
//! trailer: u64 fnv1a-64 of everything before the trailer
//! ```
//!
//! v2 — typed entries + a JSON metadata block (the rank-ladder serving
//! artifacts built by [`crate::registry`], DESIGN.md §8):
//! ```text
//! magic "TNCK" | u32 version=2 | u32 meta_len | meta JSON bytes | u32 n_entries
//! per entry: u32 name_len | name bytes | u8 dtype | u32 rank | u64 dims...
//!            | dtype 0 (f32): f32 data...
//!            | dtype 1 (int8): f32 scale | i8 data...
//!            | dtype 2 (int4): u32 group | f32 scales (n·⌈k/group⌉)
//!                              | packed nibbles (n·⌈k/2⌉ bytes)
//! trailer: u64 fnv1a-64 of everything before the trailer
//! ```
//!
//! [`artifact_from_bytes`] reads both versions (a v1 file loads as an
//! all-f32 [`Artifact`] with null metadata); [`from_bytes`] stays
//! v1-only because a [`ParamSet`] cannot represent int8 entries.
//! No serde/npy available offline; this is the crate's own format, with a
//! checksum so a torn write fails loudly instead of producing garbage
//! weights, and a save-time finiteness guard so NaN/Inf weights are
//! rejected instead of silently persisted.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::jsonx::Json;
use crate::model::ParamSet;
use crate::quant::{Q4Matrix, QMatrix};
use crate::runtime::ModelDims;
use crate::tensor::{Tensor, TensorI8};

const MAGIC: &[u8; 4] = b"TNCK";
const VERSION_V1: u32 = 1;
const VERSION_V2: u32 = 2;

const DTYPE_F32: u8 = 0;
const DTYPE_I8: u8 = 1;
const DTYPE_I4: u8 = 2;

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

fn err(msg: impl Into<String>) -> Error {
    Error::Checkpoint(msg.into())
}

/// Save-time poison guard: NaN/Inf weights decode to garbage transcripts
/// much later and much less debuggably than failing here.
fn ensure_finite(name: &str, data: &[f32]) -> Result<()> {
    if let Some(v) = data.iter().find(|v| !v.is_finite()) {
        return Err(err(format!(
            "refusing to save non-finite value {v} in tensor '{name}'"
        )));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// v1: flat f32 parameter sets.
// ---------------------------------------------------------------------------

/// Serialize a parameter set to v1 bytes.  Fails on NaN/Inf tensor data.
pub fn to_bytes(params: &ParamSet) -> Result<Vec<u8>> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&VERSION_V1.to_le_bytes());
    buf.extend_from_slice(&(params.len() as u32).to_le_bytes());
    for (name, t) in params.iter() {
        ensure_finite(name, t.data())?;
        buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
        buf.extend_from_slice(name.as_bytes());
        buf.extend_from_slice(&(t.shape().len() as u32).to_le_bytes());
        for &d in t.shape() {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for v in t.data() {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    let check = fnv1a(&buf);
    buf.extend_from_slice(&check.to_le_bytes());
    Ok(buf)
}

/// Parse the v1 entry list from a reader positioned past the version.
fn v1_tensors(r: &mut Reader) -> Result<Vec<(String, Tensor)>> {
    let n = r.u32()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let name = r.name()?;
        let shape = r.shape()?;
        let count: usize = shape.iter().product();
        out.push((name, Tensor::new(&shape, r.f32_vec(count)?)?));
    }
    Ok(out)
}

/// Parse a v1 parameter set from bytes.  v2 artifacts (typed entries)
/// must go through [`artifact_from_bytes`] instead.
pub fn from_bytes(bytes: &[u8]) -> Result<ParamSet> {
    let mut r = Reader::open(bytes)?;
    match r.version {
        VERSION_V1 => {}
        VERSION_V2 => {
            return Err(err(
                "version 2 checkpoint holds typed ladder entries; load it with \
                 checkpoint::load_artifact",
            ))
        }
        v => return Err(err(format!("unsupported checkpoint version {v}"))),
    }
    let mut params = ParamSet::new();
    for (name, t) in v1_tensors(&mut r)? {
        params.set(name, t);
    }
    Ok(params)
}

/// Save to a file (atomic: write to `.tmp`, then rename).
pub fn save(params: &ParamSet, path: impl AsRef<Path>) -> Result<()> {
    write_atomic(&to_bytes(params)?, path.as_ref())
}

/// Load from a file.
pub fn load(path: impl AsRef<Path>) -> Result<ParamSet> {
    from_bytes(&read_all(path.as_ref())?)
}

// ---------------------------------------------------------------------------
// v2: typed entries + metadata (ladder serving artifacts).
// ---------------------------------------------------------------------------

/// One typed tensor in a v2 artifact.
#[derive(Clone, Debug)]
pub enum Entry {
    F32(Tensor),
    /// Int8 weights with their quantization scale, installed verbatim by
    /// [`crate::infer::Engine::from_entries`] — no re-quantization at load.
    I8(QMatrix),
    /// Int4 weights: two nibbles per byte with per-group f32 scales, the
    /// half-size ladder rungs built by `ladder-build --bits 4`.
    I4(Q4Matrix),
}

impl Entry {
    pub fn shape(&self) -> &[usize] {
        match self {
            Entry::F32(t) => t.shape(),
            Entry::I8(q) => q.q.shape(),
            Entry::I4(q) => q.shape(),
        }
    }

    /// Scalar element count.
    pub fn len(&self) -> usize {
        match self {
            Entry::F32(t) => t.len(),
            Entry::I8(q) => q.q.data().len(),
            Entry::I4(q) => q.rows() * q.cols(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// On-device payload bytes (f32 = 4/elem; int8 = 1/elem + the scale;
    /// int4 = packed nibbles + per-group scales).
    pub fn payload_bytes(&self) -> usize {
        match self {
            Entry::F32(t) => t.len() * 4,
            Entry::I8(q) => q.q.data().len() + 4,
            Entry::I4(q) => q.payload_bytes(),
        }
    }
}

/// A v2 checkpoint: named typed entries plus a free-form JSON metadata
/// block (the rank-ladder artifacts store scheme, rank fraction, model
/// dims and per-group ν(W) diagnostics there, making each file
/// self-describing).
#[derive(Clone, Debug)]
pub struct Artifact {
    pub meta: Json,
    pub entries: BTreeMap<String, Entry>,
}

impl Artifact {
    pub fn new(meta: Json) -> Artifact {
        Artifact { meta, entries: BTreeMap::new() }
    }

    pub fn set(&mut self, name: impl Into<String>, e: Entry) {
        self.entries.insert(name.into(), e);
    }

    pub fn get(&self, name: &str) -> Result<&Entry> {
        self.entries
            .get(name)
            .ok_or_else(|| err(format!("artifact has no entry '{name}'")))
    }

    /// Total on-device weight bytes across entries.
    pub fn payload_bytes(&self) -> usize {
        self.entries.values().map(|e| e.payload_bytes()).sum()
    }
}

/// Serialize a v2 artifact.  Fails on NaN/Inf f32 data or a non-finite
/// int8 scale.
pub fn artifact_to_bytes(a: &Artifact) -> Result<Vec<u8>> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&VERSION_V2.to_le_bytes());
    let meta = a.meta.to_string_pretty();
    buf.extend_from_slice(&(meta.len() as u32).to_le_bytes());
    buf.extend_from_slice(meta.as_bytes());
    buf.extend_from_slice(&(a.entries.len() as u32).to_le_bytes());
    for (name, e) in &a.entries {
        buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
        buf.extend_from_slice(name.as_bytes());
        match e {
            Entry::F32(t) => {
                ensure_finite(name, t.data())?;
                buf.push(DTYPE_F32);
                push_shape(&mut buf, t.shape());
                for v in t.data() {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            }
            Entry::I8(q) => {
                ensure_finite(name, &[q.scale])?;
                buf.push(DTYPE_I8);
                push_shape(&mut buf, q.q.shape());
                buf.extend_from_slice(&q.scale.to_le_bytes());
                buf.extend_from_slice(bytes_of_i8(q.q.data()));
            }
            Entry::I4(q) => {
                ensure_finite(name, q.scales())?;
                buf.push(DTYPE_I4);
                push_shape(&mut buf, q.shape());
                buf.extend_from_slice(&(q.group() as u32).to_le_bytes());
                for s in q.scales() {
                    buf.extend_from_slice(&s.to_le_bytes());
                }
                buf.extend_from_slice(q.data());
            }
        }
    }
    let check = fnv1a(&buf);
    buf.extend_from_slice(&check.to_le_bytes());
    Ok(buf)
}

/// Parse an artifact from bytes — v2 natively, v1 as a backward-compatible
/// all-f32 artifact with null metadata.
pub fn artifact_from_bytes(bytes: &[u8]) -> Result<Artifact> {
    let mut r = Reader::open(bytes)?;
    match r.version {
        VERSION_V1 => {
            let mut a = Artifact::new(Json::Null);
            for (name, t) in v1_tensors(&mut r)? {
                a.set(name, Entry::F32(t));
            }
            Ok(a)
        }
        VERSION_V2 => {
            let meta_len = r.u32()? as usize;
            let meta_bytes = r.take(meta_len)?;
            let meta_text = std::str::from_utf8(meta_bytes)
                .map_err(|_| err("artifact metadata is not UTF-8"))?;
            let meta = if meta_text.is_empty() { Json::Null } else { Json::parse(meta_text)? };
            let n = r.u32()? as usize;
            let mut a = Artifact::new(meta);
            for _ in 0..n {
                let name = r.name()?;
                let dtype = r.u8()?;
                let shape = r.shape()?;
                let count: usize = shape.iter().product();
                let entry = match dtype {
                    DTYPE_F32 => Entry::F32(Tensor::new(&shape, r.f32_vec(count)?)?),
                    DTYPE_I8 => {
                        let scale = r.f32()?;
                        let data: Vec<i8> =
                            r.take(count)?.iter().map(|&b| b as i8).collect();
                        Entry::I8(QMatrix { q: TensorI8::new(&shape, data)?, scale })
                    }
                    DTYPE_I4 => {
                        if shape.len() != 2 {
                            return Err(err(format!(
                                "int4 entry '{name}' must be rank-2, got rank {}",
                                shape.len()
                            )));
                        }
                        let (n4, k4) = (shape[0], shape[1]);
                        let group = r.u32()? as usize;
                        if group == 0 {
                            return Err(err(format!("int4 entry '{name}' has group 0")));
                        }
                        let scales = r.f32_vec(n4 * k4.div_ceil(group))?;
                        let data = r.take(n4 * k4.div_ceil(2))?.to_vec();
                        Entry::I4(Q4Matrix::from_parts(n4, k4, group, data, scales).ok_or_else(
                            || err(format!("int4 entry '{name}' has inconsistent sizes")),
                        )?)
                    }
                    d => return Err(err(format!("unknown entry dtype {d} for '{name}'"))),
                };
                a.set(name, entry);
            }
            Ok(a)
        }
        v => Err(err(format!("unsupported checkpoint version {v}"))),
    }
}

/// Save a v2 artifact to a file (atomic: write to `.tmp`, then rename).
pub fn save_artifact(a: &Artifact, path: impl AsRef<Path>) -> Result<()> {
    write_atomic(&artifact_to_bytes(a)?, path.as_ref())
}

/// Load a v1 or v2 artifact from a file, verifying the checksum.
pub fn load_artifact(path: impl AsRef<Path>) -> Result<Artifact> {
    artifact_from_bytes(&read_all(path.as_ref())?)
}

// ---------------------------------------------------------------------------
// Train-state checkpoints (native trainer): params + momentum + schedule.
// ---------------------------------------------------------------------------

/// `meta.kind` of a train-state artifact.
pub const TRAIN_STATE_KIND: &str = "train-state";

/// Entry-name prefix for the optimizer's momentum buffers inside a
/// train-state artifact; everything else is a parameter.
pub const MOMENTUM_PREFIX: &str = "momentum/";

/// Optimizer/stage metadata recorded in the TNCK-v2 JSON meta block so a
/// resumed stage-2 run carries the §3.2.3 LR schedule (previously lost:
/// v1 checkpoints stored bare parameters, so `--load` restarted the
/// schedule and dropped the momentum state).
#[derive(Clone, Debug)]
pub struct TrainMeta {
    /// model layer map, so a checkpoint is servable without out-of-band
    /// dims (`ladder-build --load`, `stream-serve --load`)
    pub dims: ModelDims,
    /// 1 = stage-1 (surrogate-regularized full rank), 2 = stage-2
    pub stage: u32,
    /// epochs completed so far
    pub epoch: usize,
    /// current learning rate (post-decay — the schedule position)
    pub lr: f32,
    pub lr_decay: f32,
    /// momentum coefficient μ
    pub momentum: f32,
    /// global gradient-norm clip ceiling (0 = off)
    pub clip: f32,
    pub lam_rec: f32,
    pub lam_nonrec: f32,
    pub seed: u64,
}

/// A resumable native-trainer snapshot.
pub struct TrainState {
    pub params: ParamSet,
    pub momentum: ParamSet,
    pub meta: TrainMeta,
}

fn meta_f64(j: &Json, key: &str) -> Result<f64> {
    j.req(key)?
        .as_f64()
        .ok_or_else(|| err(format!("train-state meta '{key}' must be a number")))
}

impl TrainMeta {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str(TRAIN_STATE_KIND)),
            ("dims", self.dims.to_json()),
            ("stage", Json::num(self.stage as f64)),
            ("epoch", Json::num(self.epoch as f64)),
            ("lr", Json::num(self.lr as f64)),
            ("lr_decay", Json::num(self.lr_decay as f64)),
            ("momentum", Json::num(self.momentum as f64)),
            ("clip", Json::num(self.clip as f64)),
            ("lam_rec", Json::num(self.lam_rec as f64)),
            ("lam_nonrec", Json::num(self.lam_nonrec as f64)),
            // string, not number: a JSON f64 would silently round seeds
            // above 2^53 across save/load
            ("seed", Json::str(self.seed.to_string())),
        ])
    }

    fn from_json(j: &Json) -> Result<TrainMeta> {
        Ok(TrainMeta {
            dims: ModelDims::from_json(j.req("dims")?)?,
            stage: meta_f64(j, "stage")? as u32,
            epoch: meta_f64(j, "epoch")? as usize,
            lr: meta_f64(j, "lr")? as f32,
            lr_decay: meta_f64(j, "lr_decay")? as f32,
            momentum: meta_f64(j, "momentum")? as f32,
            clip: meta_f64(j, "clip")? as f32,
            lam_rec: meta_f64(j, "lam_rec")? as f32,
            lam_nonrec: meta_f64(j, "lam_nonrec")? as f32,
            seed: j
                .req("seed")?
                .as_str()
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| err("train-state meta 'seed' must be a u64 string"))?,
        })
    }
}

/// Is this artifact a native train-state snapshot?
pub fn is_train_state(a: &Artifact) -> bool {
    a.meta.get("kind").and_then(|k| k.as_str()) == Some(TRAIN_STATE_KIND)
}

/// Assemble a train-state artifact: parameters under their own names,
/// momentum buffers under [`MOMENTUM_PREFIX`], schedule in the meta
/// block.  All entries are f32 (training precision).
pub fn train_state_to_artifact(state: &TrainState) -> Artifact {
    let mut a = Artifact::new(state.meta.to_json());
    for (name, t) in state.params.iter() {
        a.set(name.clone(), Entry::F32(t.clone()));
    }
    for (name, t) in state.momentum.iter() {
        a.set(format!("{MOMENTUM_PREFIX}{name}"), Entry::F32(t.clone()));
    }
    a
}

/// Split a train-state artifact back into params + momentum + meta.
pub fn train_state_from_artifact(a: &Artifact) -> Result<TrainState> {
    if !is_train_state(a) {
        return Err(err("artifact is not a train-state (meta.kind mismatch)"));
    }
    let meta = TrainMeta::from_json(&a.meta)?;
    let mut params = ParamSet::new();
    let mut momentum = ParamSet::new();
    for (name, e) in &a.entries {
        let t = match e {
            Entry::F32(t) => t.clone(),
            Entry::I8(_) | Entry::I4(_) => {
                return Err(err(format!("train-state entry '{name}' must be f32")))
            }
        };
        match name.strip_prefix(MOMENTUM_PREFIX) {
            Some(base) => momentum.set(base.to_string(), t),
            None => params.set(name.clone(), t),
        }
    }
    if params.is_empty() {
        return Err(err("train-state holds no parameters"));
    }
    Ok(TrainState { params, momentum, meta })
}

/// Save a resumable train state (atomic, checksummed, finiteness-guarded
/// like every TNCK write).
pub fn save_train_state(state: &TrainState, path: impl AsRef<Path>) -> Result<()> {
    save_artifact(&train_state_to_artifact(state), path)
}

/// Load a train state saved by [`save_train_state`].
pub fn load_train_state(path: impl AsRef<Path>) -> Result<TrainState> {
    train_state_from_artifact(&load_artifact(path)?)
}

/// Extract a plain f32 [`ParamSet`] from any artifact: v1 files load
/// directly; v2 train-states contribute their parameter entries (the
/// momentum buffers are dropped); other all-f32 v2 artifacts load as-is.
/// Int8 (ladder-rung) artifacts are rejected — serve those through
/// [`crate::registry::Registry`] instead.
pub fn params_from_artifact(a: &Artifact) -> Result<ParamSet> {
    let mut params = ParamSet::new();
    for (name, e) in &a.entries {
        if name.starts_with(MOMENTUM_PREFIX) {
            continue;
        }
        match e {
            Entry::F32(t) => params.set(name.clone(), t.clone()),
            Entry::I8(_) | Entry::I4(_) => {
                return Err(err(format!(
                    "entry '{name}' is quantized — ladder artifacts cannot load as a \
                     ParamSet; use Registry::load"
                )))
            }
        }
    }
    if params.is_empty() {
        return Err(err("artifact holds no f32 parameters"));
    }
    Ok(params)
}

/// Load a parameter set from a v1 checkpoint **or** any f32 v2 artifact
/// (train-states included) — the `--load` entry point for `ladder-build`
/// and `stream-serve`, so native training output is directly servable.
pub fn load_params_any(path: impl AsRef<Path>) -> Result<ParamSet> {
    params_from_artifact(&load_artifact(path)?)
}

// ---------------------------------------------------------------------------
// Shared low-level plumbing.
// ---------------------------------------------------------------------------

fn push_shape(buf: &mut Vec<u8>, shape: &[usize]) {
    buf.extend_from_slice(&(shape.len() as u32).to_le_bytes());
    for &d in shape {
        buf.extend_from_slice(&(d as u64).to_le_bytes());
    }
}

fn bytes_of_i8(data: &[i8]) -> &[u8] {
    // i8 and u8 have identical layout; a byte-level reinterpretation is
    // the only sound way to bulk-copy without a per-element loop.
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) }
}

fn write_atomic(bytes: &[u8], path: &Path) -> Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

fn read_all(path: &Path) -> Result<Vec<u8>> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut bytes)?;
    Ok(bytes)
}

/// Checksum-verified sequential reader over a checkpoint body (the bytes
/// between the magic and the trailer), positioned just past the version.
struct Reader<'a> {
    body: &'a [u8],
    version: u32,
}

impl<'a> Reader<'a> {
    fn open(bytes: &'a [u8]) -> Result<Reader<'a>> {
        if bytes.len() < 20 {
            return Err(err("checkpoint too short"));
        }
        let (body, trailer) = bytes.split_at(bytes.len() - 8);
        let want = u64::from_le_bytes(trailer.try_into().unwrap());
        if fnv1a(body) != want {
            return Err(err("checkpoint checksum mismatch (torn write?)"));
        }
        let mut r = Reader { body, version: 0 };
        if r.take(4)? != MAGIC {
            return Err(err("not a TNCK checkpoint"));
        }
        r.version = r.u32()?;
        Ok(r)
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.body.len() < n {
            return Err(err("checkpoint truncated"));
        }
        let (a, b) = self.body.split_at(n);
        self.body = b;
        Ok(a)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn name(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        String::from_utf8(self.take(len)?.to_vec()).map_err(|_| err("bad checkpoint name"))
    }

    fn shape(&mut self) -> Result<Vec<usize>> {
        let rank = self.u32()? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(self.u64()? as usize);
        }
        Ok(shape)
    }

    fn f32_vec(&mut self, count: usize) -> Result<Vec<f32>> {
        Ok(self
            .take(count * 4)?
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;
    use crate::quant::quantize;

    fn sample() -> ParamSet {
        let mut rng = Pcg64::seeded(0);
        let mut p = ParamSet::new();
        p.set("fc_u", Tensor::randn(&[7, 3], 1.0, &mut rng));
        p.set("fc_b", Tensor::zeros(&[7]));
        p.set("scalarish", Tensor::randn(&[1], 1.0, &mut rng));
        p
    }

    #[test]
    fn roundtrip_bytes() {
        let p = sample();
        let q = from_bytes(&to_bytes(&p).unwrap()).unwrap();
        assert_eq!(p.len(), q.len());
        for (name, t) in p.iter() {
            assert_eq!(q.get(name).unwrap(), t);
        }
    }

    #[test]
    fn roundtrip_file() {
        let p = sample();
        let dir = std::env::temp_dir().join(format!("tnck-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.tnck");
        save(&p, &path).unwrap();
        let q = load(&path).unwrap();
        assert_eq!(q.get("fc_u").unwrap(), p.get("fc_u").unwrap());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corruption_detected() {
        let mut bytes = to_bytes(&sample()).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        assert!(from_bytes(&bytes).is_err());
    }

    #[test]
    fn truncation_detected() {
        let bytes = to_bytes(&sample()).unwrap();
        assert!(from_bytes(&bytes[..bytes.len() - 9]).is_err());
        assert!(from_bytes(&bytes[..10]).is_err());
    }

    #[test]
    fn rejects_wrong_magic() {
        let mut bytes = to_bytes(&sample()).unwrap();
        bytes[0] = b'X';
        // checksum still matches if we recompute; easiest corruption path is
        // magic change which breaks the checksum too
        assert!(from_bytes(&bytes).is_err());
    }

    #[test]
    fn non_finite_rejected_at_save() {
        for poison in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut p = sample();
            p.set("fc_b", Tensor::new(&[2], vec![0.0, poison]).unwrap());
            let e = to_bytes(&p).unwrap_err();
            assert!(
                matches!(e, Error::Checkpoint(_)),
                "expected Error::Checkpoint, got {e:?}"
            );
            assert!(e.to_string().contains("fc_b"), "message should name the tensor: {e}");
            let dir = std::env::temp_dir().join(format!("tnck-nan-{}", std::process::id()));
            std::fs::create_dir_all(&dir).unwrap();
            assert!(save(&p, dir.join("poisoned.tnck")).is_err());
            assert!(!dir.join("poisoned.tnck").exists(), "no partial file left behind");
            std::fs::remove_dir_all(&dir).unwrap();
        }
    }

    fn sample_artifact() -> Artifact {
        let mut rng = Pcg64::seeded(3);
        let meta = Json::obj(vec![
            ("kind", Json::str("ladder-rung")),
            ("rank_frac", Json::num(0.25)),
        ]);
        let mut a = Artifact::new(meta);
        a.set("rec0_u", Entry::I8(quantize(&Tensor::randn(&[9, 4], 0.7, &mut rng))));
        a.set("rec0_v", Entry::I8(quantize(&Tensor::randn(&[4, 6], 0.7, &mut rng))));
        a.set("gru0_b", Entry::F32(Tensor::randn(&[9], 0.1, &mut rng)));
        a
    }

    fn sample_artifact_i4() -> Artifact {
        use crate::quant::quantize4;
        let mut rng = Pcg64::seeded(11);
        let meta = Json::obj(vec![
            ("kind", Json::str("ladder-rung")),
            ("bits", Json::num(4.0)),
        ]);
        let mut a = Artifact::new(meta);
        // odd k and a ragged scale-group tail: 37 cols at group 32
        a.set("rec0_u", Entry::I4(quantize4(&Tensor::randn(&[9, 37], 0.7, &mut rng))));
        a.set("rec0_v", Entry::I4(quantize4(&Tensor::randn(&[5, 64], 0.7, &mut rng))));
        a.set("gru0_b", Entry::F32(Tensor::randn(&[9], 0.1, &mut rng)));
        a
    }

    #[test]
    fn v2_int4_roundtrip_is_bit_exact() {
        let a = sample_artifact_i4();
        let b = artifact_from_bytes(&artifact_to_bytes(&a).unwrap()).unwrap();
        assert_eq!(a.meta, b.meta);
        for (name, e) in &a.entries {
            match (e, b.get(name).unwrap()) {
                (Entry::F32(x), Entry::F32(y)) => assert_eq!(x, y),
                (Entry::I4(x), Entry::I4(y)) => {
                    assert_eq!(x.shape(), y.shape());
                    assert_eq!(x.group(), y.group());
                    assert_eq!(x.data(), y.data());
                    assert_eq!(x.scales().len(), y.scales().len());
                    for (sx, sy) in x.scales().iter().zip(y.scales()) {
                        assert_eq!(sx.to_bits(), sy.to_bits(), "scales must be bit-exact");
                    }
                }
                _ => panic!("entry '{name}' changed dtype through the roundtrip"),
            }
        }
        assert_eq!(a.payload_bytes(), b.payload_bytes());
        // 9·⌈37/2⌉ + 5·32 nibble bytes, plus (9·2 + 5·2) scales + the bias
        let rec0_u = a.get("rec0_u").unwrap();
        assert_eq!(rec0_u.payload_bytes(), 9 * 19 + 9 * 2 * 4);
        assert_eq!(rec0_u.len(), 9 * 37);
        assert_eq!(rec0_u.shape(), &[9, 37]);
    }

    #[test]
    fn int4_artifacts_rejected_by_f32_loaders() {
        let a = sample_artifact_i4();
        let e = params_from_artifact(&a).unwrap_err();
        assert!(e.to_string().contains("Registry::load"), "should point at the right API: {e}");
        assert!(train_state_from_artifact(&a).is_err());
    }

    #[test]
    fn int4_non_finite_scale_rejected() {
        use crate::quant::{quantize4, Q4_GROUP};
        let mut a = sample_artifact_i4();
        let q = quantize4(&Tensor::new(&[1, 2], vec![1.0, -1.0]).unwrap());
        let mut scales = q.scales().to_vec();
        scales[0] = f32::NAN;
        let bad =
            Q4Matrix::from_parts(1, 2, Q4_GROUP, q.data().to_vec(), scales).unwrap();
        a.set("bad_w", Entry::I4(bad));
        assert!(artifact_to_bytes(&a).is_err());
    }

    #[test]
    fn v2_roundtrip_preserves_types_scales_and_meta() {
        let a = sample_artifact();
        let b = artifact_from_bytes(&artifact_to_bytes(&a).unwrap()).unwrap();
        assert_eq!(a.meta, b.meta);
        assert_eq!(a.entries.len(), b.entries.len());
        for (name, e) in &a.entries {
            match (e, b.get(name).unwrap()) {
                (Entry::F32(x), Entry::F32(y)) => assert_eq!(x, y),
                (Entry::I8(x), Entry::I8(y)) => {
                    assert_eq!(x.q.shape(), y.q.shape());
                    assert_eq!(x.q.data(), y.q.data());
                    assert_eq!(x.scale.to_bits(), y.scale.to_bits(), "scale must be bit-exact");
                }
                _ => panic!("entry '{name}' changed dtype through the roundtrip"),
            }
        }
        assert_eq!(a.payload_bytes(), b.payload_bytes());
    }

    #[test]
    fn v2_file_roundtrip_and_corruption() {
        let a = sample_artifact();
        let dir = std::env::temp_dir().join(format!("tnck-v2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rung.tnck");
        save_artifact(&a, &path).unwrap();
        assert!(load_artifact(&path).is_ok());
        let mut bytes = artifact_to_bytes(&a).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x55;
        assert!(artifact_from_bytes(&bytes).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn v1_bytes_read_back_as_artifact() {
        let p = sample();
        let a = artifact_from_bytes(&to_bytes(&p).unwrap()).unwrap();
        assert!(a.meta.is_null());
        assert_eq!(a.entries.len(), p.len());
        for (name, t) in p.iter() {
            match a.get(name).unwrap() {
                Entry::F32(x) => assert_eq!(x, t),
                _ => panic!("v1 entries must read back as f32"),
            }
        }
    }

    #[test]
    fn v2_rejected_by_paramset_loader() {
        let bytes = artifact_to_bytes(&sample_artifact()).unwrap();
        let e = from_bytes(&bytes).unwrap_err();
        assert!(e.to_string().contains("load_artifact"), "should point at the right API: {e}");
    }

    fn sample_meta() -> TrainMeta {
        use crate::runtime::ConvDims;
        TrainMeta {
            dims: ModelDims {
                feat_dim: 8,
                conv: vec![ConvDims { context: 2, dim: 10 }],
                gru_dims: vec![8, 8],
                fc_dim: 12,
                vocab: 29,
                total_stride: 2,
            },
            stage: 2,
            epoch: 5,
            lr: 7.5e-4,
            lr_decay: 0.92,
            momentum: 0.9,
            clip: 1.5,
            lam_rec: 0.0,
            lam_nonrec: 0.0,
            // > 2^53: would corrupt if the seed went through a JSON f64
            seed: u64::MAX - 1,
        }
    }

    #[test]
    fn train_state_roundtrip_keeps_momentum_and_schedule() {
        let mut rng = Pcg64::seeded(9);
        let mut params = ParamSet::new();
        params.set("rec0_u", Tensor::randn(&[6, 2], 0.5, &mut rng));
        params.set("gru0_b", Tensor::zeros(&[6]));
        let mut momentum = ParamSet::zeros_like(&params);
        momentum.set("rec0_u", Tensor::randn(&[6, 2], 0.1, &mut rng));
        let state = TrainState { params, momentum, meta: sample_meta() };

        let art = train_state_to_artifact(&state);
        assert!(is_train_state(&art));
        let bytes = artifact_to_bytes(&art).unwrap();
        let back = train_state_from_artifact(&artifact_from_bytes(&bytes).unwrap()).unwrap();
        assert_eq!(back.params.len(), 2);
        assert_eq!(back.momentum.len(), 2);
        assert_eq!(
            back.momentum.get("rec0_u").unwrap(),
            state.momentum.get("rec0_u").unwrap(),
            "momentum buffers must survive the roundtrip"
        );
        // the schedule position survives — the ISSUE-4 satellite fix
        assert_eq!(back.meta.stage, 2);
        assert_eq!(back.meta.epoch, 5);
        assert!((back.meta.lr - 7.5e-4).abs() < 1e-9);
        assert!((back.meta.lr_decay - 0.92).abs() < 1e-6);
        assert!((back.meta.momentum - 0.9).abs() < 1e-6);
        assert!((back.meta.clip - 1.5).abs() < 1e-6);
        assert_eq!(back.meta.seed, u64::MAX - 1, "seed must round-trip exactly, not via f64");
        assert!(back.meta.dims.same_as(&state.meta.dims));

        // params_from_artifact strips the momentum entries
        let p = params_from_artifact(&art).unwrap();
        assert_eq!(p.len(), 2);
        assert!(p.contains("rec0_u") && p.contains("gru0_b"));
    }

    #[test]
    fn params_from_artifact_rejects_int8_and_v1_still_loads() {
        let a = sample_artifact(); // holds int8 rungs
        assert!(params_from_artifact(&a).is_err());
        // a v1 byte stream loads through the same any-path
        let p = sample();
        let back = params_from_artifact(&artifact_from_bytes(&to_bytes(&p).unwrap()).unwrap())
            .unwrap();
        assert_eq!(back.len(), p.len());
    }

    #[test]
    fn non_train_state_artifact_rejected_as_state() {
        assert!(train_state_from_artifact(&sample_artifact()).is_err());
    }

    #[test]
    fn non_finite_scale_rejected() {
        let mut a = sample_artifact();
        a.set(
            "bad_w",
            Entry::I8(QMatrix { q: TensorI8::new(&[1, 1], vec![1]).unwrap(), scale: f32::NAN }),
        );
        assert!(artifact_to_bytes(&a).is_err());
    }
}

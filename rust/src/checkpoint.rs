//! Checkpointing: save/load [`ParamSet`]s (and whole training states) to a
//! self-describing binary format.
//!
//! Two on-disk versions share the `TNCK` magic and fnv1a trailer:
//!
//! v1 — flat f32 parameter sets (training checkpoints):
//! ```text
//! magic "TNCK" | u32 version=1 | u32 n_entries
//! per entry: u32 name_len | name bytes | u32 rank | u64 dims... | f32 data...
//! trailer: u64 fnv1a-64 of everything before the trailer
//! ```
//!
//! v2 — typed entries + a JSON metadata block (the rank-ladder serving
//! artifacts built by [`crate::registry`], DESIGN.md §8):
//! ```text
//! magic "TNCK" | u32 version=2 | u32 meta_len | meta JSON bytes | u32 n_entries
//! per entry: u32 name_len | name bytes | u8 dtype | u32 rank | u64 dims...
//!            | dtype 0 (f32): f32 data...
//!            | dtype 1 (int8): f32 scale | i8 data...
//! trailer: u64 fnv1a-64 of everything before the trailer
//! ```
//!
//! [`artifact_from_bytes`] reads both versions (a v1 file loads as an
//! all-f32 [`Artifact`] with null metadata); [`from_bytes`] stays
//! v1-only because a [`ParamSet`] cannot represent int8 entries.
//! No serde/npy available offline; this is the crate's own format, with a
//! checksum so a torn write fails loudly instead of producing garbage
//! weights, and a save-time finiteness guard so NaN/Inf weights are
//! rejected instead of silently persisted.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::jsonx::Json;
use crate::model::ParamSet;
use crate::quant::QMatrix;
use crate::tensor::{Tensor, TensorI8};

const MAGIC: &[u8; 4] = b"TNCK";
const VERSION_V1: u32 = 1;
const VERSION_V2: u32 = 2;

const DTYPE_F32: u8 = 0;
const DTYPE_I8: u8 = 1;

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

fn err(msg: impl Into<String>) -> Error {
    Error::Checkpoint(msg.into())
}

/// Save-time poison guard: NaN/Inf weights decode to garbage transcripts
/// much later and much less debuggably than failing here.
fn ensure_finite(name: &str, data: &[f32]) -> Result<()> {
    if let Some(v) = data.iter().find(|v| !v.is_finite()) {
        return Err(err(format!(
            "refusing to save non-finite value {v} in tensor '{name}'"
        )));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// v1: flat f32 parameter sets.
// ---------------------------------------------------------------------------

/// Serialize a parameter set to v1 bytes.  Fails on NaN/Inf tensor data.
pub fn to_bytes(params: &ParamSet) -> Result<Vec<u8>> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&VERSION_V1.to_le_bytes());
    buf.extend_from_slice(&(params.len() as u32).to_le_bytes());
    for (name, t) in params.iter() {
        ensure_finite(name, t.data())?;
        buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
        buf.extend_from_slice(name.as_bytes());
        buf.extend_from_slice(&(t.shape().len() as u32).to_le_bytes());
        for &d in t.shape() {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for v in t.data() {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    let check = fnv1a(&buf);
    buf.extend_from_slice(&check.to_le_bytes());
    Ok(buf)
}

/// Parse the v1 entry list from a reader positioned past the version.
fn v1_tensors(r: &mut Reader) -> Result<Vec<(String, Tensor)>> {
    let n = r.u32()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let name = r.name()?;
        let shape = r.shape()?;
        let count: usize = shape.iter().product();
        out.push((name, Tensor::new(&shape, r.f32_vec(count)?)?));
    }
    Ok(out)
}

/// Parse a v1 parameter set from bytes.  v2 artifacts (typed entries)
/// must go through [`artifact_from_bytes`] instead.
pub fn from_bytes(bytes: &[u8]) -> Result<ParamSet> {
    let mut r = Reader::open(bytes)?;
    match r.version {
        VERSION_V1 => {}
        VERSION_V2 => {
            return Err(err(
                "version 2 checkpoint holds typed ladder entries; load it with \
                 checkpoint::load_artifact",
            ))
        }
        v => return Err(err(format!("unsupported checkpoint version {v}"))),
    }
    let mut params = ParamSet::new();
    for (name, t) in v1_tensors(&mut r)? {
        params.set(name, t);
    }
    Ok(params)
}

/// Save to a file (atomic: write to `.tmp`, then rename).
pub fn save(params: &ParamSet, path: impl AsRef<Path>) -> Result<()> {
    write_atomic(&to_bytes(params)?, path.as_ref())
}

/// Load from a file.
pub fn load(path: impl AsRef<Path>) -> Result<ParamSet> {
    from_bytes(&read_all(path.as_ref())?)
}

// ---------------------------------------------------------------------------
// v2: typed entries + metadata (ladder serving artifacts).
// ---------------------------------------------------------------------------

/// One typed tensor in a v2 artifact.
#[derive(Clone, Debug)]
pub enum Entry {
    F32(Tensor),
    /// Int8 weights with their quantization scale, installed verbatim by
    /// [`crate::infer::Engine::from_entries`] — no re-quantization at load.
    I8(QMatrix),
}

impl Entry {
    pub fn shape(&self) -> &[usize] {
        match self {
            Entry::F32(t) => t.shape(),
            Entry::I8(q) => q.q.shape(),
        }
    }

    /// Scalar element count.
    pub fn len(&self) -> usize {
        match self {
            Entry::F32(t) => t.len(),
            Entry::I8(q) => q.q.data().len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// On-device payload bytes (f32 = 4/elem; int8 = 1/elem + the scale).
    pub fn payload_bytes(&self) -> usize {
        match self {
            Entry::F32(t) => t.len() * 4,
            Entry::I8(q) => q.q.data().len() + 4,
        }
    }
}

/// A v2 checkpoint: named typed entries plus a free-form JSON metadata
/// block (the rank-ladder artifacts store scheme, rank fraction, model
/// dims and per-group ν(W) diagnostics there, making each file
/// self-describing).
#[derive(Clone, Debug)]
pub struct Artifact {
    pub meta: Json,
    pub entries: BTreeMap<String, Entry>,
}

impl Artifact {
    pub fn new(meta: Json) -> Artifact {
        Artifact { meta, entries: BTreeMap::new() }
    }

    pub fn set(&mut self, name: impl Into<String>, e: Entry) {
        self.entries.insert(name.into(), e);
    }

    pub fn get(&self, name: &str) -> Result<&Entry> {
        self.entries
            .get(name)
            .ok_or_else(|| err(format!("artifact has no entry '{name}'")))
    }

    /// Total on-device weight bytes across entries.
    pub fn payload_bytes(&self) -> usize {
        self.entries.values().map(|e| e.payload_bytes()).sum()
    }
}

/// Serialize a v2 artifact.  Fails on NaN/Inf f32 data or a non-finite
/// int8 scale.
pub fn artifact_to_bytes(a: &Artifact) -> Result<Vec<u8>> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&VERSION_V2.to_le_bytes());
    let meta = a.meta.to_string_pretty();
    buf.extend_from_slice(&(meta.len() as u32).to_le_bytes());
    buf.extend_from_slice(meta.as_bytes());
    buf.extend_from_slice(&(a.entries.len() as u32).to_le_bytes());
    for (name, e) in &a.entries {
        buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
        buf.extend_from_slice(name.as_bytes());
        match e {
            Entry::F32(t) => {
                ensure_finite(name, t.data())?;
                buf.push(DTYPE_F32);
                push_shape(&mut buf, t.shape());
                for v in t.data() {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            }
            Entry::I8(q) => {
                ensure_finite(name, &[q.scale])?;
                buf.push(DTYPE_I8);
                push_shape(&mut buf, q.q.shape());
                buf.extend_from_slice(&q.scale.to_le_bytes());
                buf.extend_from_slice(bytes_of_i8(q.q.data()));
            }
        }
    }
    let check = fnv1a(&buf);
    buf.extend_from_slice(&check.to_le_bytes());
    Ok(buf)
}

/// Parse an artifact from bytes — v2 natively, v1 as a backward-compatible
/// all-f32 artifact with null metadata.
pub fn artifact_from_bytes(bytes: &[u8]) -> Result<Artifact> {
    let mut r = Reader::open(bytes)?;
    match r.version {
        VERSION_V1 => {
            let mut a = Artifact::new(Json::Null);
            for (name, t) in v1_tensors(&mut r)? {
                a.set(name, Entry::F32(t));
            }
            Ok(a)
        }
        VERSION_V2 => {
            let meta_len = r.u32()? as usize;
            let meta_bytes = r.take(meta_len)?;
            let meta_text = std::str::from_utf8(meta_bytes)
                .map_err(|_| err("artifact metadata is not UTF-8"))?;
            let meta = if meta_text.is_empty() { Json::Null } else { Json::parse(meta_text)? };
            let n = r.u32()? as usize;
            let mut a = Artifact::new(meta);
            for _ in 0..n {
                let name = r.name()?;
                let dtype = r.u8()?;
                let shape = r.shape()?;
                let count: usize = shape.iter().product();
                let entry = match dtype {
                    DTYPE_F32 => Entry::F32(Tensor::new(&shape, r.f32_vec(count)?)?),
                    DTYPE_I8 => {
                        let scale = r.f32()?;
                        let data: Vec<i8> =
                            r.take(count)?.iter().map(|&b| b as i8).collect();
                        Entry::I8(QMatrix { q: TensorI8::new(&shape, data)?, scale })
                    }
                    d => return Err(err(format!("unknown entry dtype {d} for '{name}'"))),
                };
                a.set(name, entry);
            }
            Ok(a)
        }
        v => Err(err(format!("unsupported checkpoint version {v}"))),
    }
}

/// Save a v2 artifact to a file (atomic: write to `.tmp`, then rename).
pub fn save_artifact(a: &Artifact, path: impl AsRef<Path>) -> Result<()> {
    write_atomic(&artifact_to_bytes(a)?, path.as_ref())
}

/// Load a v1 or v2 artifact from a file, verifying the checksum.
pub fn load_artifact(path: impl AsRef<Path>) -> Result<Artifact> {
    artifact_from_bytes(&read_all(path.as_ref())?)
}

// ---------------------------------------------------------------------------
// Shared low-level plumbing.
// ---------------------------------------------------------------------------

fn push_shape(buf: &mut Vec<u8>, shape: &[usize]) {
    buf.extend_from_slice(&(shape.len() as u32).to_le_bytes());
    for &d in shape {
        buf.extend_from_slice(&(d as u64).to_le_bytes());
    }
}

fn bytes_of_i8(data: &[i8]) -> &[u8] {
    // i8 and u8 have identical layout; a byte-level reinterpretation is
    // the only sound way to bulk-copy without a per-element loop.
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) }
}

fn write_atomic(bytes: &[u8], path: &Path) -> Result<()> {
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

fn read_all(path: &Path) -> Result<Vec<u8>> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut bytes)?;
    Ok(bytes)
}

/// Checksum-verified sequential reader over a checkpoint body (the bytes
/// between the magic and the trailer), positioned just past the version.
struct Reader<'a> {
    body: &'a [u8],
    version: u32,
}

impl<'a> Reader<'a> {
    fn open(bytes: &'a [u8]) -> Result<Reader<'a>> {
        if bytes.len() < 20 {
            return Err(err("checkpoint too short"));
        }
        let (body, trailer) = bytes.split_at(bytes.len() - 8);
        let want = u64::from_le_bytes(trailer.try_into().unwrap());
        if fnv1a(body) != want {
            return Err(err("checkpoint checksum mismatch (torn write?)"));
        }
        let mut r = Reader { body, version: 0 };
        if r.take(4)? != MAGIC {
            return Err(err("not a TNCK checkpoint"));
        }
        r.version = r.u32()?;
        Ok(r)
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.body.len() < n {
            return Err(err("checkpoint truncated"));
        }
        let (a, b) = self.body.split_at(n);
        self.body = b;
        Ok(a)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn name(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        String::from_utf8(self.take(len)?.to_vec()).map_err(|_| err("bad checkpoint name"))
    }

    fn shape(&mut self) -> Result<Vec<usize>> {
        let rank = self.u32()? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(self.u64()? as usize);
        }
        Ok(shape)
    }

    fn f32_vec(&mut self, count: usize) -> Result<Vec<f32>> {
        Ok(self
            .take(count * 4)?
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;
    use crate::quant::quantize;

    fn sample() -> ParamSet {
        let mut rng = Pcg64::seeded(0);
        let mut p = ParamSet::new();
        p.set("fc_u", Tensor::randn(&[7, 3], 1.0, &mut rng));
        p.set("fc_b", Tensor::zeros(&[7]));
        p.set("scalarish", Tensor::randn(&[1], 1.0, &mut rng));
        p
    }

    #[test]
    fn roundtrip_bytes() {
        let p = sample();
        let q = from_bytes(&to_bytes(&p).unwrap()).unwrap();
        assert_eq!(p.len(), q.len());
        for (name, t) in p.iter() {
            assert_eq!(q.get(name).unwrap(), t);
        }
    }

    #[test]
    fn roundtrip_file() {
        let p = sample();
        let dir = std::env::temp_dir().join(format!("tnck-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.tnck");
        save(&p, &path).unwrap();
        let q = load(&path).unwrap();
        assert_eq!(q.get("fc_u").unwrap(), p.get("fc_u").unwrap());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corruption_detected() {
        let mut bytes = to_bytes(&sample()).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        assert!(from_bytes(&bytes).is_err());
    }

    #[test]
    fn truncation_detected() {
        let bytes = to_bytes(&sample()).unwrap();
        assert!(from_bytes(&bytes[..bytes.len() - 9]).is_err());
        assert!(from_bytes(&bytes[..10]).is_err());
    }

    #[test]
    fn rejects_wrong_magic() {
        let mut bytes = to_bytes(&sample()).unwrap();
        bytes[0] = b'X';
        // checksum still matches if we recompute; easiest corruption path is
        // magic change which breaks the checksum too
        assert!(from_bytes(&bytes).is_err());
    }

    #[test]
    fn non_finite_rejected_at_save() {
        for poison in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut p = sample();
            p.set("fc_b", Tensor::new(&[2], vec![0.0, poison]).unwrap());
            let e = to_bytes(&p).unwrap_err();
            assert!(
                matches!(e, Error::Checkpoint(_)),
                "expected Error::Checkpoint, got {e:?}"
            );
            assert!(e.to_string().contains("fc_b"), "message should name the tensor: {e}");
            let dir = std::env::temp_dir().join(format!("tnck-nan-{}", std::process::id()));
            std::fs::create_dir_all(&dir).unwrap();
            assert!(save(&p, dir.join("poisoned.tnck")).is_err());
            assert!(!dir.join("poisoned.tnck").exists(), "no partial file left behind");
            std::fs::remove_dir_all(&dir).unwrap();
        }
    }

    fn sample_artifact() -> Artifact {
        let mut rng = Pcg64::seeded(3);
        let meta = Json::obj(vec![
            ("kind", Json::str("ladder-rung")),
            ("rank_frac", Json::num(0.25)),
        ]);
        let mut a = Artifact::new(meta);
        a.set("rec0_u", Entry::I8(quantize(&Tensor::randn(&[9, 4], 0.7, &mut rng))));
        a.set("rec0_v", Entry::I8(quantize(&Tensor::randn(&[4, 6], 0.7, &mut rng))));
        a.set("gru0_b", Entry::F32(Tensor::randn(&[9], 0.1, &mut rng)));
        a
    }

    #[test]
    fn v2_roundtrip_preserves_types_scales_and_meta() {
        let a = sample_artifact();
        let b = artifact_from_bytes(&artifact_to_bytes(&a).unwrap()).unwrap();
        assert_eq!(a.meta, b.meta);
        assert_eq!(a.entries.len(), b.entries.len());
        for (name, e) in &a.entries {
            match (e, b.get(name).unwrap()) {
                (Entry::F32(x), Entry::F32(y)) => assert_eq!(x, y),
                (Entry::I8(x), Entry::I8(y)) => {
                    assert_eq!(x.q.shape(), y.q.shape());
                    assert_eq!(x.q.data(), y.q.data());
                    assert_eq!(x.scale.to_bits(), y.scale.to_bits(), "scale must be bit-exact");
                }
                _ => panic!("entry '{name}' changed dtype through the roundtrip"),
            }
        }
        assert_eq!(a.payload_bytes(), b.payload_bytes());
    }

    #[test]
    fn v2_file_roundtrip_and_corruption() {
        let a = sample_artifact();
        let dir = std::env::temp_dir().join(format!("tnck-v2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rung.tnck");
        save_artifact(&a, &path).unwrap();
        assert!(load_artifact(&path).is_ok());
        let mut bytes = artifact_to_bytes(&a).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x55;
        assert!(artifact_from_bytes(&bytes).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn v1_bytes_read_back_as_artifact() {
        let p = sample();
        let a = artifact_from_bytes(&to_bytes(&p).unwrap()).unwrap();
        assert!(a.meta.is_null());
        assert_eq!(a.entries.len(), p.len());
        for (name, t) in p.iter() {
            match a.get(name).unwrap() {
                Entry::F32(x) => assert_eq!(x, t),
                Entry::I8(_) => panic!("v1 entries must read back as f32"),
            }
        }
    }

    #[test]
    fn v2_rejected_by_paramset_loader() {
        let bytes = artifact_to_bytes(&sample_artifact()).unwrap();
        let e = from_bytes(&bytes).unwrap_err();
        assert!(e.to_string().contains("load_artifact"), "should point at the right API: {e}");
    }

    #[test]
    fn non_finite_scale_rejected() {
        let mut a = sample_artifact();
        a.set(
            "bad_w",
            Entry::I8(QMatrix { q: TensorI8::new(&[1, 1], vec![1]).unwrap(), scale: f32::NAN }),
        );
        assert!(artifact_to_bytes(&a).is_err());
    }
}

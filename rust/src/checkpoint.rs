//! Checkpointing: save/load [`ParamSet`]s (and whole training states) to a
//! self-describing binary format.
//!
//! Format (little-endian):
//! ```text
//! magic "TNCK" | u32 version | u32 n_entries
//! per entry: u32 name_len | name bytes | u32 rank | u64 dims... | f32 data...
//! trailer: u64 fnv1a-64 of everything before the trailer
//! ```
//! No serde/npy available offline; this is the crate's own format, with a
//! checksum so a torn write fails loudly instead of producing garbage
//! weights.

use std::io::{Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::model::ParamSet;
use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"TNCK";
const VERSION: u32 = 1;

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Serialize a parameter set to bytes.
pub fn to_bytes(params: &ParamSet) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&(params.len() as u32).to_le_bytes());
    for (name, t) in params.iter() {
        buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
        buf.extend_from_slice(name.as_bytes());
        buf.extend_from_slice(&(t.shape().len() as u32).to_le_bytes());
        for &d in t.shape() {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for v in t.data() {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    let check = fnv1a(&buf);
    buf.extend_from_slice(&check.to_le_bytes());
    buf
}

/// Parse a parameter set from bytes.
pub fn from_bytes(bytes: &[u8]) -> Result<ParamSet> {
    if bytes.len() < 20 {
        return Err(Error::other("checkpoint too short"));
    }
    let (body, trailer) = bytes.split_at(bytes.len() - 8);
    let want = u64::from_le_bytes(trailer.try_into().unwrap());
    if fnv1a(body) != want {
        return Err(Error::other("checkpoint checksum mismatch (torn write?)"));
    }
    let mut r = body;
    let mut take = |n: usize| -> Result<&[u8]> {
        if r.len() < n {
            return Err(Error::other("checkpoint truncated"));
        }
        let (a, b) = r.split_at(n);
        r = b;
        Ok(a)
    };
    if take(4)? != MAGIC {
        return Err(Error::other("not a TNCK checkpoint"));
    }
    let version = u32::from_le_bytes(take(4)?.try_into().unwrap());
    if version != VERSION {
        return Err(Error::other(format!("unsupported checkpoint version {version}")));
    }
    let n = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
    let mut params = ParamSet::new();
    for _ in 0..n {
        let name_len = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
        let name = String::from_utf8(take(name_len)?.to_vec())
            .map_err(|_| Error::other("bad checkpoint name"))?;
        let rank = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(u64::from_le_bytes(take(8)?.try_into().unwrap()) as usize);
        }
        let count: usize = shape.iter().product();
        let raw = take(count * 4)?;
        let data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        params.set(name, Tensor::new(&shape, data)?);
    }
    Ok(params)
}

/// Save to a file (atomic: write to `.tmp`, then rename).
pub fn save(params: &ParamSet, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&to_bytes(params))?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Load from a file.
pub fn load(path: impl AsRef<Path>) -> Result<ParamSet> {
    let mut bytes = Vec::new();
    std::fs::File::open(path.as_ref())?.read_to_end(&mut bytes)?;
    from_bytes(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Pcg64;

    fn sample() -> ParamSet {
        let mut rng = Pcg64::seeded(0);
        let mut p = ParamSet::new();
        p.set("fc_u", Tensor::randn(&[7, 3], 1.0, &mut rng));
        p.set("fc_b", Tensor::zeros(&[7]));
        p.set("scalarish", Tensor::randn(&[1], 1.0, &mut rng));
        p
    }

    #[test]
    fn roundtrip_bytes() {
        let p = sample();
        let q = from_bytes(&to_bytes(&p)).unwrap();
        assert_eq!(p.len(), q.len());
        for (name, t) in p.iter() {
            assert_eq!(q.get(name).unwrap(), t);
        }
    }

    #[test]
    fn roundtrip_file() {
        let p = sample();
        let dir = std::env::temp_dir().join(format!("tnck-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.tnck");
        save(&p, &path).unwrap();
        let q = load(&path).unwrap();
        assert_eq!(q.get("fc_u").unwrap(), p.get("fc_u").unwrap());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corruption_detected() {
        let mut bytes = to_bytes(&sample());
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        assert!(from_bytes(&bytes).is_err());
    }

    #[test]
    fn truncation_detected() {
        let bytes = to_bytes(&sample());
        assert!(from_bytes(&bytes[..bytes.len() - 9]).is_err());
        assert!(from_bytes(&bytes[..10]).is_err());
    }

    #[test]
    fn rejects_wrong_magic() {
        let mut bytes = to_bytes(&sample());
        bytes[0] = b'X';
        // checksum still matches if we recompute; easiest corruption path is
        // magic change which breaks the checksum too
        assert!(from_bytes(&bytes).is_err());
    }
}

//! # tracenorm
//!
//! Reproduction of *"Trace norm regularization and faster inference for
//! embedded speech recognition RNNs"* (Kliegl, Goyal, Zhao, Srinet,
//! Shoeybi; Baidu SVAIL, 2017) as a three-layer Rust + JAX + Pallas stack.
//!
//! Layer map (see DESIGN.md):
//! * **L3 (this crate)** — training orchestrator (two-stage trace-norm
//!   scheme, SVD warmstart), the sharded multi-threaded serving runtime
//!   ([`stream`]/[`shard`]/[`serve`]) with its rank-ladder model
//!   registry and adaptive-fidelity controller
//!   ([`registry`]/[`controller`]), and the pure-Rust embedded int8
//!   inference engine with the reproduced "farm" low-batch GEMM kernels.
//! * **L2/L1 (python/, build-time only)** — the DS2-style GRU acoustic
//!   model and its Pallas kernels, AOT-lowered to `artifacts/*.hlo.txt`
//!   and executed here through the PJRT CPU client ([`runtime`]).
//!
//! Substrate modules ([`tensor`], [`linalg`], [`jsonx`], [`prng`], …) are
//! implemented in-repo: the build environment is offline, so everything
//! beyond the `xla` crate closure is first-party code.

pub mod autograd;
pub mod checkpoint;
pub mod cli;
pub mod configx;
pub mod controller;
pub mod data;
pub mod decoder;
pub mod devicesim;
pub mod error;
pub mod experiments;
pub mod infer;
pub mod jsonx;
pub mod kernels;
pub mod linalg;
pub mod lm;
pub mod metricsx;
pub mod model;
pub mod obs;
pub mod prng;
pub mod proplite;
pub mod quant;
pub mod registry;
pub mod runtime;
pub mod serve;
pub mod shard;
pub mod stream;
pub mod tensor;
pub mod train;

pub use error::{Error, Result};

/// Compile-time `Send + Sync` proof helper for the sharded serving
/// runtime's shared-plan types (DESIGN.md §9): modules assert their
/// thread-safety with `const _: () = crate::assert_send_sync::<T>();`
/// so a future non-Sync field fails the build, not a serve.
pub(crate) const fn assert_send_sync<T: Send + Sync>() {}

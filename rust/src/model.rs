//! Model parameter management on the Rust side.
//!
//! The coordinator owns the training state: parameters are plain
//! [`Tensor`]s keyed by the names in the artifact manifest, fed to the
//! AOT train/eval executables in name-sorted order and read back the same
//! way.  This module implements:
//!
//! * initialization (Glorot weights / zero biases), matching the L2 init
//!   family;
//! * the paper's **stage-1 → stage-2 SVD warmstart** (§3): materialize
//!   each compressible group `W = U·V` (or take the dense `W`), truncate
//!   its SVD by explained variance, and split into balanced factors
//!   `U√Σ, √Σ Vᵀ` at the target rank;
//! * rank selection against the AOT rank ladder;
//! * magnitude-pruning masks (the Fig. 8 sparsity baseline);
//! * ν(W) diagnostics per group (Figs. 2/3).

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::linalg::{self, Svd};
use crate::prng::Pcg64;
use crate::runtime::{ArtifactSpec, Value};
use crate::tensor::Tensor;

/// Named parameter set (flat, name-sorted wire order).
#[derive(Clone, Debug, Default)]
pub struct ParamSet {
    map: BTreeMap<String, Tensor>,
}

impl ParamSet {
    pub fn new() -> Self {
        Self::default()
    }

    /// Initialize parameters for an artifact: Glorot-uniform for weight
    /// matrices, zeros for biases (`*_b`).
    pub fn init(spec: &ArtifactSpec, seed: u64) -> Result<ParamSet> {
        let mut rng = Pcg64::seeded(seed);
        let mut map = BTreeMap::new();
        for name in &spec.param_names {
            let shape = spec.input_shape(name)?;
            let t = if name.ends_with("_b") {
                Tensor::zeros(shape)
            } else if shape.len() == 2 {
                Tensor::glorot(shape[0], shape[1], &mut rng)
            } else {
                let mut t = Tensor::zeros(shape);
                rng.fill_normal(t.data_mut(), 0.01);
                t
            };
            map.insert(name.clone(), t);
        }
        Ok(ParamSet { map })
    }

    pub fn zeros_like(other: &ParamSet) -> ParamSet {
        ParamSet {
            map: other
                .map
                .iter()
                .map(|(k, v)| (k.clone(), Tensor::zeros(v.shape())))
                .collect(),
        }
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map
            .get(name)
            .ok_or_else(|| Error::other(format!("no param '{name}'")))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        self.map
            .get_mut(name)
            .ok_or_else(|| Error::other(format!("no param '{name}'")))
    }

    pub fn set(&mut self, name: impl Into<String>, t: Tensor) {
        self.map.insert(name.into(), t);
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Tensor)> {
        self.map.iter()
    }

    pub fn iter_mut(&mut self) -> impl Iterator<Item = (&String, &mut Tensor)> {
        self.map.iter_mut()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Total scalar parameter count (the paper's x-axis in Figs. 4/8).
    pub fn num_scalars(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }

    /// Values in the order of `names` (artifact wire order).
    pub fn values_in_order(&self, names: &[String]) -> Result<Vec<Value>> {
        names
            .iter()
            .map(|n| Ok(Value::F32(self.get(n)?.clone())))
            .collect()
    }

    /// Rebuild from artifact outputs (first `names.len()` outputs).
    pub fn from_values(names: &[String], values: &[Value]) -> Result<ParamSet> {
        if values.len() < names.len() {
            return Err(Error::other("not enough output values for params"));
        }
        let mut map = BTreeMap::new();
        for (n, v) in names.iter().zip(values) {
            map.insert(n.clone(), v.as_f32()?.clone());
        }
        Ok(ParamSet { map })
    }

    /// Elementwise multiply masked weights (`g_w *= g_mask`) — keeps pruned
    /// entries at exactly zero between steps.
    pub fn apply_masks(&mut self, masks: &ParamSet) -> Result<()> {
        for (mname, m) in masks.iter() {
            let wname = mname
                .strip_suffix("_mask")
                .map(|b| format!("{b}_w"))
                .ok_or_else(|| Error::other("mask name must end in _mask"))?;
            if let Some(w) = self.map.get_mut(&wname) {
                w.mul_assign(m)?;
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Native (manifest-free) initialization.
// ---------------------------------------------------------------------------

/// Stage-1 initialization for the native trainer ([`crate::train`]):
/// every compressible group starts as **full-rank balanced factors**
/// `{base}_u (m, r)` / `{base}_v (r, n)` with `r = min(m, n)` — the
/// paper's stage-1 factored parameterization (§3.1), under which the
/// `½(‖U‖²+‖V‖²)` surrogate is trained.  Conv and the output projection
/// stay dense; biases start at zero; weights are Glorot-uniform.  The
/// layer map mirrors [`crate::infer::Engine::from_params`] exactly.
pub fn init_factored_full(dims: &crate::runtime::ModelDims, seed: u64) -> ParamSet {
    init_native(dims, true, seed)
}

/// Dense (unfactored) initialization on the same layer map — the ℓ²
/// baseline of the paper's comparisons.
pub fn init_dense(dims: &crate::runtime::ModelDims, seed: u64) -> ParamSet {
    init_native(dims, false, seed)
}

fn init_native(dims: &crate::runtime::ModelDims, factored: bool, seed: u64) -> ParamSet {
    let mut rng = Pcg64::seeded(seed);
    let mut p = ParamSet::new();
    let set_group = |p: &mut ParamSet, base: &str, m: usize, n: usize, rng: &mut Pcg64| {
        if factored {
            let r = m.min(n);
            p.set(format!("{base}_u"), Tensor::glorot(m, r, rng));
            p.set(format!("{base}_v"), Tensor::glorot(r, n, rng));
        } else {
            p.set(format!("{base}_w"), Tensor::glorot(m, n, rng));
        }
    };
    let mut prev = dims.feat_dim;
    for (i, c) in dims.conv.iter().enumerate() {
        p.set(format!("conv{i}_w"), Tensor::glorot(c.dim, c.context * prev, &mut rng));
        p.set(format!("conv{i}_b"), Tensor::zeros(&[c.dim]));
        prev = c.dim;
    }
    for (i, &h) in dims.gru_dims.iter().enumerate() {
        set_group(&mut p, &format!("rec{i}"), 3 * h, h, &mut rng);
        set_group(&mut p, &format!("nonrec{i}"), 3 * h, prev, &mut rng);
        p.set(format!("gru{i}_b"), Tensor::zeros(&[3 * h]));
        prev = h;
    }
    set_group(&mut p, "fc", dims.fc_dim, prev, &mut rng);
    p.set("fc_b", Tensor::zeros(&[dims.fc_dim]));
    p.set("out_w", Tensor::glorot(dims.vocab, dims.fc_dim, &mut rng));
    p.set("out_b", Tensor::zeros(&[dims.vocab]));
    p
}

/// Do these parameters implement the layer map `dims` describes (group
/// out/in dims, factor inner ranks, bias lengths)?  The clean-error
/// gate for untrusted `--load` checkpoints on the native training path —
/// without it a mismatched layer map panics inside a GEMM contraction
/// assert mid-run instead of failing at construction (mirrors the
/// validation [`crate::infer::Engine::from_entries`] applies to ladder
/// artifacts).
pub fn check_params_match_dims(params: &ParamSet, dims: &crate::runtime::ModelDims) -> Result<()> {
    let matrix = |name: &str| -> Result<&Tensor> {
        let t = params.get(name)?;
        if t.rank() != 2 {
            return Err(Error::Shape(format!("'{name}' must be a matrix, got {:?}", t.shape())));
        }
        Ok(t)
    };
    // (out, in) dims of a possibly-factored group
    let group_dims = |base: &str| -> Result<(usize, usize)> {
        if params.contains(&format!("{base}_u")) {
            let u = matrix(&format!("{base}_u"))?;
            let v = matrix(&format!("{base}_v"))?;
            if u.cols() != v.rows() {
                return Err(Error::Shape(format!("{base}: factor inner ranks disagree")));
            }
            Ok((u.rows(), v.cols()))
        } else {
            let w = matrix(&format!("{base}_w"))?;
            Ok((w.rows(), w.cols()))
        }
    };
    let err = |what: &str| {
        Err(Error::Shape(format!(
            "checkpoint {what} does not match the model dims (layer-map mismatch?)"
        )))
    };
    let stride: usize = dims.conv.iter().map(|c| c.context).product();
    if stride != dims.total_stride {
        return Err(Error::Shape(format!(
            "model dims are self-inconsistent: conv contexts multiply to {stride} but \
             total_stride is {}",
            dims.total_stride
        )));
    }
    let mut prev = dims.feat_dim;
    for (i, c) in dims.conv.iter().enumerate() {
        let (o, inp) = group_dims(&format!("conv{i}"))?;
        if o != c.dim
            || inp != c.context * prev
            || params.get(&format!("conv{i}_b"))?.len() != c.dim
        {
            return err(&format!("conv{i}"));
        }
        prev = c.dim;
    }
    for (i, &h) in dims.gru_dims.iter().enumerate() {
        let (ro, ri) = group_dims(&format!("rec{i}"))?;
        let (no, ni) = group_dims(&format!("nonrec{i}"))?;
        if ro != 3 * h
            || ri != h
            || no != 3 * h
            || ni != prev
            || params.get(&format!("gru{i}_b"))?.len() != 3 * h
        {
            return err(&format!("gru layer {i}"));
        }
        prev = h;
    }
    let (fo, fi) = group_dims("fc")?;
    if fo != dims.fc_dim || fi != prev || params.get("fc_b")?.len() != dims.fc_dim {
        return err("fc");
    }
    let out = matrix("out_w")?;
    if out.rows() != dims.vocab
        || out.cols() != dims.fc_dim
        || params.get("out_b")?.len() != dims.vocab
    {
        return err("the output projection");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Compressible groups.
// ---------------------------------------------------------------------------

/// Compressible weight-group base names present in a parameter set:
/// factored groups appear as `{base}_u`/`{base}_v`; dense compressible
/// groups are `rec*/nonrec*/grujoint*/fc` `_w` matrices (conv and the
/// output projection are not compressed — paper §3.2).
pub fn group_bases(params: &ParamSet) -> Vec<String> {
    let mut bases = Vec::new();
    for name in params.names() {
        if let Some(base) = name.strip_suffix("_u") {
            bases.push(base.to_string());
        } else if let Some(base) = name.strip_suffix("_w") {
            if base.starts_with("rec")
                || base.starts_with("nonrec")
                || base.starts_with("grujoint")
                || base == "fc"
            {
                bases.push(base.to_string());
            }
        }
    }
    bases.sort();
    bases.dedup();
    bases
}

/// Is this group regularized by λ_rec (vs λ_nonrec)?  Mirrors the L2 rule.
pub fn is_recurrent_group(base: &str) -> bool {
    base.starts_with("rec") || base.starts_with("grujoint")
}

/// Materialize the dense matrix of a group (`U·V` if factored).
pub fn group_matrix(params: &ParamSet, base: &str) -> Result<Tensor> {
    if params.contains(&format!("{base}_u")) {
        let u = params.get(&format!("{base}_u"))?;
        let v = params.get(&format!("{base}_v"))?;
        u.matmul(v)
    } else {
        Ok(params.get(&format!("{base}_w"))?.clone())
    }
}

/// Per-group SVD diagnostics for a parameter set (Figs. 2/3).
pub struct GroupDiag {
    pub base: String,
    pub nu: f32,
    pub rank90: usize,
    pub full_rank: usize,
    pub svd: Svd,
}

pub fn diagnose_groups(params: &ParamSet) -> Result<Vec<GroupDiag>> {
    group_bases(params)
        .into_iter()
        .map(|base| {
            let w = group_matrix(params, &base)?;
            let svd = linalg::svd(&w)?;
            let nu = linalg::nu_from_singular_values(&svd.s)?;
            let rank90 = svd.rank_for_variance(0.90);
            let full_rank = w.rows().min(w.cols());
            Ok(GroupDiag { base, nu, rank90, full_rank, svd })
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Stage-1 → stage-2 warmstart.
// ---------------------------------------------------------------------------

/// Warmstart a stage-2 parameter set from stage-1 parameters (§3):
/// for each factored group in the target artifact, take the stage-1 dense
/// matrix (materializing U·V if stage 1 was factored), truncate its SVD at
/// the target rank, and install balanced factors.  Everything else is
/// copied (shapes must match).
pub fn warmstart(stage1: &ParamSet, target: &ArtifactSpec, seed: u64) -> Result<ParamSet> {
    let mut out = ParamSet::init(target, seed)?; // placeholder init for safety
    for name in &target.param_names {
        if let Some(base) = name.strip_suffix("_u") {
            let w = group_matrix(stage1, base)?;
            let shape_u = target.input_shape(name)?;
            let r = shape_u[1];
            let svd = linalg::svd(&w)?;
            let (u, v) = svd.balanced_factors(r);
            if u.shape() != shape_u {
                return Err(Error::Shape(format!(
                    "warmstart {base}: U {:?} vs target {:?}",
                    u.shape(),
                    shape_u
                )));
            }
            out.set(format!("{base}_u"), u);
            out.set(format!("{base}_v"), v);
        } else if name.ends_with("_v") {
            // handled with _u
        } else if stage1.contains(name) {
            let src = stage1.get(name)?;
            if src.shape() != target.input_shape(name)? {
                return Err(Error::Shape(format!("warmstart copy {name}: shape mismatch")));
            }
            out.set(name.clone(), src.clone());
        }
        // params absent from stage 1 (scheme change) keep their fresh init
    }
    Ok(out)
}

/// Truncated-SVD factorization of every compressible group at a fixed
/// fraction of full rank: each group's dense matrix (materializing
/// `U·V` if the source is already factored) is truncated at
/// `r = clamp(ceil(rank_frac · min(m,n)), 1, min(m,n))` and replaced by
/// balanced factors `{base}_u`/`{base}_v`; everything else is copied
/// verbatim.  This is the per-rung transform of the offline
/// `ladder-build` pass ([`crate::registry`], DESIGN.md §8) — the same
/// truncate-and-balance rule [`warmstart`] applies, but driven by an
/// explicit rank fraction instead of a target artifact's shapes.
pub fn truncate_groups(params: &ParamSet, rank_frac: f64) -> Result<ParamSet> {
    Ok(truncate_groups_diag(params, rank_frac)?.0)
}

/// [`truncate_groups`] plus per-group ν(W) of the *truncated* matrices,
/// computed from the singular values the truncation already holds (the
/// truncated spectrum is exactly `s[..r]` padded with zeros) — no second
/// SVD.  The ladder build stores these ν values in each rung's metadata.
pub fn truncate_groups_diag(
    params: &ParamSet,
    rank_frac: f64,
) -> Result<(ParamSet, Vec<(String, f32)>)> {
    if !(rank_frac > 0.0 && rank_frac <= 1.0) {
        return Err(Error::Config(format!("rank_frac {rank_frac} not in (0, 1]")));
    }
    let bases = group_bases(params);
    let mut out = ParamSet::new();
    for (name, t) in params.iter() {
        let in_group = bases.iter().any(|b| {
            name == &format!("{b}_u") || name == &format!("{b}_v") || name == &format!("{b}_w")
        });
        if !in_group {
            out.set(name.clone(), t.clone());
        }
    }
    let mut nu = Vec::with_capacity(bases.len());
    for base in &bases {
        let w = group_matrix(params, base)?;
        let full = w.rows().min(w.cols());
        let r = ((full as f64 * rank_frac).ceil() as usize).clamp(1, full);
        let svd = linalg::svd(&w)?;
        let mut truncated_s = svd.s.clone();
        for s in truncated_s.iter_mut().skip(r) {
            *s = 0.0;
        }
        nu.push((base.clone(), linalg::nu_from_singular_values(&truncated_s)?));
        let (u, v) = svd.balanced_factors(r);
        out.set(format!("{base}_u"), u);
        out.set(format!("{base}_v"), v);
    }
    Ok((out, nu))
}

/// Choose the smallest ladder rung whose rank fraction is ≥ the fraction
/// needed to explain `threshold` variance in the *worst* group (so every
/// group meets the paper's explained-variance criterion).
pub fn pick_rank_frac(stage1: &ParamSet, threshold: f64, ladder: &[f64]) -> Result<f64> {
    let mut needed: f64 = 0.0;
    for base in group_bases(stage1) {
        let w = group_matrix(stage1, &base)?;
        let svd = linalg::svd(&w)?;
        let r = svd.rank_for_variance(threshold);
        let full = w.rows().min(w.cols());
        needed = needed.max(r as f64 / full as f64);
    }
    let mut rungs: Vec<f64> = ladder.to_vec();
    rungs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for rung in &rungs {
        if *rung + 1e-9 >= needed {
            return Ok(*rung);
        }
    }
    Ok(*rungs.last().ok_or_else(|| Error::other("empty rank ladder"))?)
}

// ---------------------------------------------------------------------------
// Magnitude pruning (Fig. 8 sparsity baseline).
// ---------------------------------------------------------------------------

/// Build masks zeroing the smallest-magnitude `sparsity` fraction of each
/// compressible group's weights.
pub fn magnitude_masks(params: &ParamSet, sparsity: f64) -> Result<ParamSet> {
    let mut masks = ParamSet::new();
    for base in group_bases(params) {
        let w = params.get(&format!("{base}_w"))?;
        let mut mags: Vec<f32> = w.data().iter().map(|v| v.abs()).collect();
        mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let cut_idx = ((mags.len() as f64) * sparsity) as usize;
        let cut = if cut_idx == 0 { -1.0 } else { mags[cut_idx.min(mags.len() - 1)] };
        let data: Vec<f32> = w
            .data()
            .iter()
            .map(|v| if v.abs() > cut { 1.0 } else { 0.0 })
            .collect();
        masks.set(format!("{base}_mask"), Tensor::new(w.shape(), data)?);
    }
    Ok(masks)
}

/// Fraction of nonzero entries across all masked groups.
pub fn mask_density(masks: &ParamSet) -> f64 {
    let (mut nz, mut total) = (0usize, 0usize);
    for (_, m) in masks.iter() {
        nz += m.data().iter().filter(|v| **v != 0.0).count();
        total += m.len();
    }
    if total == 0 {
        1.0
    } else {
        nz as f64 / total as f64
    }
}

/// Effective (post-mask) nonzero parameter count: masked groups count
/// their surviving weights; everything else counts fully.
pub fn effective_params(params: &ParamSet, masks: &ParamSet) -> usize {
    let mut count = 0usize;
    for (name, t) in params.iter() {
        if let Some(base) = name.strip_suffix("_w") {
            if let Ok(m) = masks.get(&format!("{base}_mask")) {
                count += m.data().iter().filter(|v| **v != 0.0).count();
                continue;
            }
        }
        count += t.len();
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Dtype, IoSpec};

    fn fake_spec(params: &[(&str, &[usize])]) -> ArtifactSpec {
        ArtifactSpec {
            name: "t".into(),
            file: "t.hlo.txt".into(),
            kind: "train".into(),
            config: "c".into(),
            scheme: "partial".into(),
            rank_frac: None,
            use_masks: false,
            param_names: params.iter().map(|(n, _)| n.to_string()).collect(),
            mask_names: vec![],
            inputs: params
                .iter()
                .map(|(n, s)| IoSpec { name: n.to_string(), shape: s.to_vec(), dtype: Dtype::F32 })
                .collect(),
            outputs: vec![],
            batch: None,
            chunk: None,
        }
    }

    #[test]
    fn init_zero_bias_glorot_weights() {
        let spec = fake_spec(&[("fc_u", &[8, 4]), ("fc_v", &[4, 6]), ("fc_b", &[8])]);
        let p = ParamSet::init(&spec, 0).unwrap();
        assert!(p.get("fc_b").unwrap().data().iter().all(|&v| v == 0.0));
        assert!(p.get("fc_u").unwrap().abs_max() > 0.0);
        assert_eq!(p.num_scalars(), 8 * 4 + 4 * 6 + 8);
    }

    #[test]
    fn init_factored_full_matches_engine_layer_map() {
        use crate::runtime::{ConvDims, ModelDims};
        let dims = ModelDims {
            feat_dim: 6,
            conv: vec![ConvDims { context: 2, dim: 8 }],
            gru_dims: vec![5, 7],
            fc_dim: 9,
            vocab: 11,
            total_stride: 2,
        };
        let p = init_factored_full(&dims, 0);
        // full-rank factors: rec0 is (15, 5) => r = 5
        assert_eq!(p.get("rec0_u").unwrap().shape(), &[15, 5]);
        assert_eq!(p.get("rec0_v").unwrap().shape(), &[5, 5]);
        // nonrec1 maps gru0 (5) -> 3*7: r = min(21, 5) = 5
        assert_eq!(p.get("nonrec1_u").unwrap().shape(), &[21, 5]);
        assert_eq!(p.get("nonrec1_v").unwrap().shape(), &[5, 5]);
        assert_eq!(p.get("conv0_w").unwrap().shape(), &[8, 12]);
        assert_eq!(p.get("out_w").unwrap().shape(), &[11, 9]);
        // servable as-is by the embedded engine
        assert!(crate::infer::Engine::from_params(
            &dims,
            "partial",
            &p,
            crate::infer::Precision::F32,
            4
        )
        .is_ok());
        let d = init_dense(&dims, 0);
        assert_eq!(d.get("rec0_w").unwrap().shape(), &[15, 5]);
        assert!(!d.contains("rec0_u"));
    }

    #[test]
    fn check_params_match_dims_gates_layer_map_mismatches() {
        use crate::runtime::{ConvDims, ModelDims};
        let dims = ModelDims {
            feat_dim: 6,
            conv: vec![ConvDims { context: 2, dim: 8 }],
            gru_dims: vec![5, 7],
            fc_dim: 9,
            vocab: 11,
            total_stride: 2,
        };
        let p = init_factored_full(&dims, 1);
        assert!(check_params_match_dims(&p, &dims).is_ok());
        let d = init_dense(&dims, 1);
        assert!(check_params_match_dims(&d, &dims).is_ok());

        // truncated groups still match (rank lives on the inner dim)
        let trunc = truncate_groups(&p, 0.5).unwrap();
        assert!(check_params_match_dims(&trunc, &dims).is_ok());

        // a wider network must be rejected with a clean shape error
        let mut wide = dims.clone();
        wide.gru_dims = vec![16, 16];
        let e = check_params_match_dims(&p, &wide).unwrap_err();
        assert!(matches!(e, Error::Shape(_)), "expected shape error, got {e:?}");
        // missing a layer entirely is also an error (extra layer in dims)
        let mut deeper = dims.clone();
        deeper.gru_dims.push(5);
        assert!(check_params_match_dims(&p, &deeper).is_err());
    }

    #[test]
    fn group_bases_found() {
        let spec = fake_spec(&[
            ("rec0_u", &[6, 2]),
            ("rec0_v", &[2, 2]),
            ("fc_w", &[4, 4]),
            ("conv0_w", &[4, 4]),
            ("out_w", &[4, 4]),
        ]);
        let p = ParamSet::init(&spec, 0).unwrap();
        assert_eq!(group_bases(&p), vec!["fc".to_string(), "rec0".to_string()]);
        assert!(is_recurrent_group("rec0"));
        assert!(!is_recurrent_group("fc"));
        assert!(!is_recurrent_group("nonrec1"));
    }

    #[test]
    fn warmstart_full_rank_reproduces_group() {
        // stage 1: dense fc_w; target: factored at full rank
        let mut stage1 = ParamSet::new();
        let mut rng = Pcg64::seeded(3);
        let w = Tensor::randn(&[8, 6], 1.0, &mut rng);
        stage1.set("fc_w", w.clone());
        stage1.set("fc_b", Tensor::zeros(&[8]));
        let target = fake_spec(&[("fc_u", &[8, 6]), ("fc_v", &[6, 6]), ("fc_b", &[8])]);
        let p2 = warmstart(&stage1, &target, 0).unwrap();
        let rec = p2.get("fc_u").unwrap().matmul(p2.get("fc_v").unwrap()).unwrap();
        assert!(w.max_abs_diff(&rec) < 1e-3);
        assert!(p2.get("fc_b").unwrap().data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn warmstart_truncates_to_target_rank() {
        let mut stage1 = ParamSet::new();
        let mut rng = Pcg64::seeded(4);
        // near-rank-2 matrix
        let a = Tensor::randn(&[8, 2], 1.0, &mut rng);
        let b = Tensor::randn(&[2, 6], 1.0, &mut rng);
        let w = a.matmul(&b).unwrap();
        stage1.set("fc_w", w.clone());
        let target = fake_spec(&[("fc_u", &[8, 2]), ("fc_v", &[2, 6])]);
        let p2 = warmstart(&stage1, &target, 0).unwrap();
        let rec = p2.get("fc_u").unwrap().matmul(p2.get("fc_v").unwrap()).unwrap();
        assert!(w.max_abs_diff(&rec) < 1e-3); // rank-2 source: exact at rank 2
    }

    #[test]
    fn warmstart_from_factored_stage1() {
        let mut stage1 = ParamSet::new();
        let mut rng = Pcg64::seeded(8);
        let u = Tensor::randn(&[8, 8], 0.5, &mut rng);
        let v = Tensor::randn(&[8, 6], 0.5, &mut rng);
        stage1.set("rec0_u", u.clone());
        stage1.set("rec0_v", v.clone());
        let target = fake_spec(&[("rec0_u", &[8, 6]), ("rec0_v", &[6, 6])]);
        let p2 = warmstart(&stage1, &target, 0).unwrap();
        let w = u.matmul(&v).unwrap();
        let rec = p2.get("rec0_u").unwrap().matmul(p2.get("rec0_v").unwrap()).unwrap();
        // full min(m,n) rank retained => exact reconstruction
        assert!(w.max_abs_diff(&rec) < 1e-3);
    }

    #[test]
    fn truncate_groups_full_rank_reproduces_and_low_rank_shrinks() {
        let mut p = ParamSet::new();
        let mut rng = Pcg64::seeded(9);
        let w = Tensor::randn(&[10, 8], 1.0, &mut rng);
        p.set("fc_w", w.clone());
        p.set("fc_b", Tensor::zeros(&[10]));
        p.set("out_w", Tensor::randn(&[5, 10], 1.0, &mut rng)); // not a group

        let full = truncate_groups(&p, 1.0).unwrap();
        let rec = full.get("fc_u").unwrap().matmul(full.get("fc_v").unwrap()).unwrap();
        assert!(w.max_abs_diff(&rec) < 1e-3);
        assert!(!full.contains("fc_w"), "group weight replaced by factors");
        assert_eq!(full.get("out_w").unwrap(), p.get("out_w").unwrap());
        assert!(full.get("fc_b").unwrap().data().iter().all(|&v| v == 0.0));

        let quarter = truncate_groups(&p, 0.25).unwrap();
        assert_eq!(quarter.get("fc_u").unwrap().shape(), &[10, 2]); // ceil(0.25*8)
        assert_eq!(quarter.get("fc_v").unwrap().shape(), &[2, 8]);
        assert!(quarter.num_scalars() < full.num_scalars());

        assert!(truncate_groups(&p, 0.0).is_err());
        assert!(truncate_groups(&p, 1.5).is_err());
    }

    #[test]
    fn pick_rank_frac_prefers_small_rungs_for_low_rank() {
        let mut p = ParamSet::new();
        let mut rng = Pcg64::seeded(5);
        let a = Tensor::randn(&[16, 2], 1.0, &mut rng);
        let b = Tensor::randn(&[2, 16], 1.0, &mut rng);
        p.set("fc_w", a.matmul(&b).unwrap());
        let frac = pick_rank_frac(&p, 0.9, &[0.125, 0.25, 0.5, 0.75]).unwrap();
        assert_eq!(frac, 0.125); // rank 2 of 16 = 0.125
        let mut hi = ParamSet::new();
        hi.set("fc_w", Tensor::randn(&[16, 16], 1.0, &mut rng));
        let frac_hi = pick_rank_frac(&hi, 0.95, &[0.125, 0.25, 0.5, 0.75]).unwrap();
        assert!(frac_hi >= 0.5);
    }

    #[test]
    fn magnitude_masks_hit_target_sparsity() {
        let mut p = ParamSet::new();
        let mut rng = Pcg64::seeded(6);
        p.set("fc_w", Tensor::randn(&[32, 32], 1.0, &mut rng));
        let masks = magnitude_masks(&p, 0.75).unwrap();
        let density = mask_density(&masks);
        assert!((density - 0.25).abs() < 0.02, "density {density}");
        // masked weights are the small ones
        let mut p2 = p.clone();
        p2.apply_masks(&masks).unwrap();
        let kept_min = p2
            .get("fc_w")
            .unwrap()
            .data()
            .iter()
            .filter(|v| **v != 0.0)
            .fold(f32::MAX, |m, v| m.min(v.abs()));
        let dropped_max = p
            .get("fc_w")
            .unwrap()
            .data()
            .iter()
            .zip(masks.get("fc_mask").unwrap().data())
            .filter(|(_, m)| **m == 0.0)
            .fold(0.0f32, |mx, (v, _)| mx.max(v.abs()));
        assert!(kept_min >= dropped_max);
        assert_eq!(
            effective_params(&p2, &masks),
            masks.get("fc_mask").unwrap().data().iter().filter(|v| **v != 0.0).count()
        );
    }

    #[test]
    fn diagnose_groups_reports_nu() {
        let mut p = ParamSet::new();
        let mut rng = Pcg64::seeded(7);
        p.set("rec0_w", Tensor::randn(&[12, 12], 1.0, &mut rng));
        let d = diagnose_groups(&p).unwrap();
        assert_eq!(d.len(), 1);
        assert!(d[0].nu > 0.0 && d[0].nu < 1.0);
        assert!(d[0].rank90 <= 12);
    }
}

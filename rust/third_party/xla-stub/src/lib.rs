//! Offline stub of the `xla` crate (xla-rs over xla_extension 0.5.1).
//!
//! The build environment has no network and no XLA toolchain, so this
//! crate mirrors the subset of the xla-rs API that `tracenorm::runtime`
//! consumes: literals, the PJRT CPU client, and HLO-text loading.  Type
//! signatures match the real bindings; anything that would touch the
//! PJRT runtime returns [`Error`] at runtime instead.
//!
//! To execute real artifacts, point the `xla` path dependency in
//! `rust/Cargo.toml` at the actual bindings — no source change in
//! `tracenorm` is required.

use std::fmt;

/// Error type matching `xla::Error`'s public face (Display + std::error).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT unavailable (offline xla-stub build; swap \
         rust/third_party/xla-stub for the real xla bindings)"
    ))
}

/// Element types used by the tracenorm artifacts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    F32,
    F64,
}

/// Scalar types that can cross the literal boundary.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i8 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host-side literal (tensor value).
#[derive(Clone, Debug, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(unavailable("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module (text interchange format).
#[derive(Clone, Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// A computation ready to compile.
#[derive(Clone, Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer returned by execution.
#[derive(Clone, Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle.  `cpu()` succeeds so manifest-only flows work;
/// compilation is where the stub reports itself.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailability() {
        assert!(PjRtClient::cpu().is_ok());
        let err = HloModuleProto::from_text_file("x.hlo.txt").unwrap_err();
        assert!(err.to_string().contains("xla-stub"));
    }
}

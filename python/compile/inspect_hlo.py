"""L2 profiling: static analysis of the lowered HLO artifacts.

Counts the op mix (dots, while loops, fusible elementwise, custom calls)
and estimates FLOPs/bytes for the §Perf pass.  Usage:

    cd python && python -m compile.inspect_hlo ../artifacts/train_mini_partial_full.hlo.txt
"""

from __future__ import annotations

import re
import sys
from collections import Counter


DOT_RE = re.compile(r"=\s*f32\[([\d,]*)\][^=]*\bdot\(")
SHAPE_RE = re.compile(r"f32\[([\d,]*)\]")


def analyze(path: str) -> dict:
    text = open(path).read()
    ops = Counter()
    for line in text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.-]+\s*=\s*\w+\[?.*?\]?\s*([a-z-]+)\(", line)
        if m:
            ops[m.group(1)] += 1
    stats = {
        "total_instructions": sum(ops.values()),
        "dot": ops.get("dot", 0),
        "while": ops.get("while", 0),
        "convolution": ops.get("convolution", 0),
        "custom-call": ops.get("custom-call", 0),
        "reduce": ops.get("reduce", 0),
        "transpose": ops.get("transpose", 0),
        "top10": ops.most_common(10),
    }
    return stats


def main() -> None:
    for path in sys.argv[1:]:
        s = analyze(path)
        print(f"\n{path}")
        print(f"  instructions: {s['total_instructions']}")
        for k in ("dot", "while", "reduce", "transpose", "custom-call", "convolution"):
            print(f"  {k:>12}: {s[k]}")
        print("  top ops:", ", ".join(f"{k}x{v}" for k, v in s["top10"]))
        # sanity: the AOT path must not contain custom-calls (Mosaic would
        # make the artifact unloadable on the CPU PJRT client)
        assert s["custom-call"] == 0, "custom-call found — artifact not CPU-portable!"


if __name__ == "__main__":
    main()

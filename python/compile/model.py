"""L2: the Deep Speech 2–style acoustic model, loss and train step.

This module defines every function that gets AOT-lowered to HLO text for
the Rust coordinator (aot.py):

  * ``forward``       — full-utterance eval: feats -> logprobs
  * ``train_step``    — SGD-with-momentum step with the paper's losses:
        - factored schemes: trace-norm surrogate
          ``l(UV) + λ/2 (||U||_F² + ||V||_F²)``   (paper eq. (3)/(5))
        - unfactored: ℓ² penalty ``λ/2 ||W||_F²`` (the paper's baseline)
        - optional weight masks (magnitude-pruning baseline, Fig. 8)
      λ_rec / λ_nonrec are *runtime inputs*, so a single artifact serves
      the whole Figure-1 grid sweep.
  * ``stream_step``   — chunked streaming inference with carried GRU state
      (f32, or int8 via the L1 quantized kernel).

Parameters cross the Rust boundary as a flat, name-sorted tuple; the
ordering and shapes are recorded in artifacts/manifest.json by aot.py.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import kernels
from .configs import (
    SCHEME_PARTIAL,
    SCHEME_SPLIT,
    SCHEME_UNFACTORED,
    BatchSpec,
    ModelConfig,
)
from .ctc import ctc_loss_mean
from .layers import (
    Params,
    apply_group,
    conv_frontend,
    fc_softmax,
    group_full_shape,
    group_names,
    gru_layer,
    is_recurrent_group,
)

# Optimizer: RMSProp with gradient-norm clipping.  (The paper trains with
# SGD+momentum over 40 WSJ epochs; on this single-core testbed RMSProp
# reaches the same qualitative regime in ~10 synthetic epochs, and the
# optimizer state stays a single buffer so the Rust wire format is
# unchanged.  DESIGN.md §3 records the substitution.)
RMS_DECAY = 0.9
RMS_EPS = 1e-6
GRAD_CLIP = 5.0


# --------------------------------------------------------------------------
# Parameter schema + init.
# --------------------------------------------------------------------------


def param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    """Flat name -> shape map. Sorted(name) is the wire order to Rust."""
    shapes: Dict[str, Tuple[int, ...]] = {}
    prev = cfg.feat_dim
    for i, spec in enumerate(cfg.conv):
        shapes[f"conv{i}_w"] = (spec.dim, spec.context * prev)
        shapes[f"conv{i}_b"] = (spec.dim,)
        prev = spec.dim
    for i, h in enumerate(cfg.gru_dims):
        shapes[f"gru{i}_b"] = (3 * h,)
    for name in group_names(cfg):
        m, n = group_full_shape(cfg, name)
        if cfg.scheme == SCHEME_UNFACTORED:
            shapes[f"{name}_w"] = (m, n)
        else:
            r = cfg.rank_of((m, n))
            shapes[f"{name}_u"] = (m, r)
            shapes[f"{name}_v"] = (r, n)
    shapes["fc_b"] = (cfg.fc_dim,)
    shapes["out_w"] = (cfg.vocab, cfg.fc_dim)
    shapes["out_b"] = (cfg.vocab,)
    return shapes


def mask_names(cfg: ModelConfig) -> List[str]:
    """Weight-mask input names (unfactored + use_masks only)."""
    if not cfg.use_masks:
        return []
    assert cfg.scheme == SCHEME_UNFACTORED, "masks model unstructured sparsity"
    return [f"{g}_mask" for g in group_names(cfg)]


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """Glorot-uniform weights, zero biases (matches the Rust-side init)."""
    shapes = param_shapes(cfg)
    key = jax.random.PRNGKey(seed)
    params: Params = {}
    for name in sorted(shapes):
        shape = shapes[name]
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[-1]
            fan_out = shape[0]
            lim = math.sqrt(6.0 / (fan_in + fan_out))
            params[name] = jax.random.uniform(
                sub, shape, jnp.float32, minval=-lim, maxval=lim
            )
    return params


# --------------------------------------------------------------------------
# Forward + loss.
# --------------------------------------------------------------------------


def forward(
    cfg: ModelConfig,
    params: Params,
    feats: jnp.ndarray,
    frame_lens: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """feats: (B, T, F) raw frames -> (logprobs (B, T', V), out_lens (B,))."""
    x = conv_frontend(cfg, params, feats)
    b, t, _ = x.shape
    for i, h in enumerate(cfg.gru_dims):
        h0 = jnp.zeros((b, h), jnp.float32)
        x, _ = gru_layer(cfg, params, i, x, h0)
    logp = fc_softmax(cfg, params, x)
    out_lens = frame_lens // cfg.total_stride
    return logp, out_lens


def regularization_penalty(
    cfg: ModelConfig,
    params: Params,
    lam_rec: jnp.ndarray,
    lam_nonrec: jnp.ndarray,
) -> jnp.ndarray:
    """The paper's penalties over the four compressible layers.

    Factored schemes: λ_g/2 (||U||_F² + ||V||_F²)  — trace-norm surrogate.
    Unfactored:       λ_g/2 ||W||_F²               — the ℓ² baseline.
    (Conv, output projection and biases are not compressed in the paper and
    are left unregularized so the comparison targets the same weights.)
    """
    pen = jnp.zeros((), jnp.float32)
    for name in group_names(cfg):
        lam = lam_rec if is_recurrent_group(name) else lam_nonrec
        if cfg.scheme == SCHEME_UNFACTORED:
            w = params[f"{name}_w"]
            pen = pen + 0.5 * lam * jnp.sum(w * w)
        else:
            u = params[f"{name}_u"]
            v = params[f"{name}_v"]
            pen = pen + 0.5 * lam * (jnp.sum(u * u) + jnp.sum(v * v))
    return pen


def loss_fn(
    cfg: ModelConfig,
    params: Params,
    feats: jnp.ndarray,
    frame_lens: jnp.ndarray,
    labels: jnp.ndarray,
    label_lens: jnp.ndarray,
    lam_rec: jnp.ndarray,
    lam_nonrec: jnp.ndarray,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logp, out_lens = forward(cfg, params, feats, frame_lens)
    ctc, _ = ctc_loss_mean(logp, out_lens, labels, label_lens)
    pen = regularization_penalty(cfg, params, lam_rec, lam_nonrec)
    return ctc + pen, {"ctc": ctc, "penalty": pen}


# --------------------------------------------------------------------------
# SGD-with-momentum train step (grad-norm clipped), as one jittable fn.
# --------------------------------------------------------------------------


def global_norm(tree: Params) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(v.astype(jnp.float32) ** 2) for v in jax.tree_util.tree_leaves(tree))
    )


def train_step(
    cfg: ModelConfig,
    params: Params,
    momentum: Params,
    feats: jnp.ndarray,
    frame_lens: jnp.ndarray,
    labels: jnp.ndarray,
    label_lens: jnp.ndarray,
    lr: jnp.ndarray,
    lam_rec: jnp.ndarray,
    lam_nonrec: jnp.ndarray,
) -> Tuple[Params, Params, Dict[str, jnp.ndarray]]:
    """One clipped SGD-momentum step.  Masked weights (if any) stay masked:
    the mask multiplies the weight in the forward pass, so pruned entries
    receive gradient only through the mask product (zero), and the Rust
    coordinator additionally re-projects after each step."""
    (loss, aux), grads = jax.value_and_grad(
        lambda p: loss_fn(
            cfg, p, feats, frame_lens, labels, label_lens, lam_rec, lam_nonrec
        ),
        has_aux=True,
    )(params)
    # Masks are inputs, not trainables: drop their grads if present.
    grads = {k: g for k, g in grads.items() if not k.endswith("_mask")}
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, GRAD_CLIP / jnp.maximum(gnorm, 1e-12))
    new_m: Params = {}
    new_p: Params = {}
    for k in sorted(grads):
        g = scale * grads[k]
        v = RMS_DECAY * momentum[k] + (1.0 - RMS_DECAY) * g * g
        new_m[k] = v
        new_p[k] = params[k] - lr * g / (jnp.sqrt(v) + RMS_EPS)
    metrics = {
        "loss": loss,
        "ctc": aux["ctc"],
        "penalty": aux["penalty"],
        "grad_norm": gnorm,
    }
    return new_p, new_m, metrics


# --------------------------------------------------------------------------
# Streaming chunk step (server-path latency experiments).
# --------------------------------------------------------------------------


def stream_step(
    cfg: ModelConfig,
    params: Params,
    hs: Sequence[jnp.ndarray],
    chunk: jnp.ndarray,
) -> Tuple[List[jnp.ndarray], jnp.ndarray]:
    """One streaming chunk: (carried GRU states, (1, Tc, F)) ->
    (new states, logprobs (1, Tc', V)).

    The chunk length (a multiple of the total stride) is the paper's §4
    time-batching knob: the non-recurrent GEMMs inside gru_layer batch
    across Tc' timesteps while the recurrent GEMM stays batch-1.
    """
    x = conv_frontend(cfg, params, chunk)
    new_hs: List[jnp.ndarray] = []
    for i, _h in enumerate(cfg.gru_dims):
        x, h_last = gru_layer(cfg, params, i, x, hs[i])
        new_hs.append(h_last)
    logp = fc_softmax(cfg, params, x)
    return new_hs, logp


# --------------------------------------------------------------------------
# Int8 streaming variant: weights arrive pre-quantized (int8 + scale per
# group factor); the dense applications go through the L1 int8 kernel.
# Models the paper's §4 embedded path at the HLO level.
# --------------------------------------------------------------------------


def quantized_param_names(cfg: ModelConfig) -> List[str]:
    """Names of dense weights that get int8-quantized in the int8 stream
    artifact. Biases and the tiny output projection stay f32."""
    names: List[str] = []
    for i in range(len(cfg.conv)):
        names.append(f"conv{i}_w")
    for g in group_names(cfg):
        if cfg.scheme == SCHEME_UNFACTORED:
            names.append(f"{g}_w")
        else:
            names.append(f"{g}_u")
            names.append(f"{g}_v")
    names.append("out_w")
    return names


def _q_apply(params: Params, name: str, x: jnp.ndarray) -> jnp.ndarray:
    """x @ W.T with W given as (int8 q, f32 scale). Activations are
    quantized symmetrically per call (dynamic quantization, as the paper's
    runtime does per GEMM)."""
    q = params[f"{name}_q"]
    w_scale = params[f"{name}_scale"]
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    x_scale = amax / 127.0
    xq = jnp.clip(jnp.round(x / x_scale), -127, 127).astype(jnp.int8)
    return kernels.int8_gemm(xq, q, x_scale.reshape(1), w_scale.reshape(1))


def stream_step_int8(
    cfg: ModelConfig,
    params: Params,
    hs: Sequence[jnp.ndarray],
    chunk: jnp.ndarray,
) -> Tuple[List[jnp.ndarray], jnp.ndarray]:
    """Int8 analog of stream_step (factored schemes only)."""
    assert cfg.scheme != SCHEME_UNFACTORED

    def apply2(name: str, x: jnp.ndarray) -> jnp.ndarray:
        t = _q_apply(params, f"{name}_v", x)
        return _q_apply(params, f"{name}_u", t)

    x = chunk
    from .layers import stack_frames  # local import to avoid cycle noise

    for i, spec in enumerate(cfg.conv):
        x = stack_frames(x, spec.context)
        b, t, d = x.shape
        y = _q_apply(params, f"conv{i}_w", x.reshape(b * t, d)) + params[f"conv{i}_b"]
        x = jax.nn.relu(y).reshape(b, t, spec.dim)

    new_hs: List[jnp.ndarray] = []
    for i, h in enumerate(cfg.gru_dims):
        b, t, din = x.shape
        bias = params[f"gru{i}_b"]
        gx = (apply2(f"nonrec{i}", x.reshape(b * t, din)) + bias).reshape(b, t, 3 * h)

        def step(hprev, gx_t):
            gh = apply2(f"rec{i}", hprev)
            hnew = kernels.gru_gates(gx_t, gh, hprev)
            return hnew, hnew

        h_last, xs = jax.lax.scan(step, hs[i], gx.transpose(1, 0, 2))
        x = xs.transpose(1, 0, 2)
        new_hs.append(h_last)

    b, t, d = x.shape
    y = apply2("fc", x.reshape(b * t, d)) + params["fc_b"]
    y = jax.nn.relu(y)
    logits = _q_apply(params, "out_w", y) + params["out_b"]
    logp = jax.nn.log_softmax(logits, axis=-1).reshape(b, t, cfg.vocab)
    return new_hs, logp

"""L2 building blocks: frontend, factored weight application, GRU layers.

Every dense application goes through the L1 Pallas kernels
(``kernels.matmul_t`` / ``kernels.lowrank_apply`` / ``kernels.gru_gates`` /
``kernels.int8_gemm``) so that the lowered HLO contains exactly the
schedules described in DESIGN.md §Hardware-Adaptation.

Weight-group schemes (paper App. B.2):
  * ``unfactored``: one dense (3H, ·) matrix per group.
  * ``partial`` (the paper's choice): the 3 recurrent matrices of a GRU are
    concatenated into one ``rec`` group (3H, H) and factored as U·V; same
    for the 3 non-recurrent matrices (3H, Din).
  * ``split``: each of the 6 matrices factored separately.
  * ``joint``: one (3H, Din+H) matrix over [x; h] factored as a whole —
    maximal sharing, but the non-recurrent half can no longer be batched
    across time (exactly the efficiency argument of App. B.2).
"""

from __future__ import annotations

from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import kernels
from .configs import (
    SCHEME_JOINT,
    SCHEME_PARTIAL,
    SCHEME_SPLIT,
    SCHEME_UNFACTORED,
    ModelConfig,
)

Params = Dict[str, jnp.ndarray]


# --------------------------------------------------------------------------
# Frontend: non-overlapping frame stacking + linear + ReLU ("conv" layers).
# Stride == context keeps streaming chunk-exact (configs.ConvSpec).
# --------------------------------------------------------------------------


def stack_frames(x: jnp.ndarray, context: int) -> jnp.ndarray:
    """(B, T, F) -> (B, T // context, context * F); truncates ragged tail."""
    b, t, f = x.shape
    t2 = t // context
    return x[:, : t2 * context].reshape(b, t2, context * f)


def conv_frontend(cfg: ModelConfig, params: Params, feats: jnp.ndarray) -> jnp.ndarray:
    """Apply the stacked-frame projection stack. (B, T, F) -> (B, T', D)."""
    x = feats
    for i, spec in enumerate(cfg.conv):
        x = stack_frames(x, spec.context)
        b, t, d = x.shape
        w = params[f"conv{i}_w"]  # (dim, context * prev)
        y = kernels.matmul_t(x.reshape(b * t, d), w) + params[f"conv{i}_b"]
        x = jax.nn.relu(y).reshape(b, t, spec.dim)
    return x


# --------------------------------------------------------------------------
# Weight application by scheme.
# --------------------------------------------------------------------------


def group_names(cfg: ModelConfig) -> List[str]:
    """Names of the compressible weight groups (3 GRUs + FC — paper §3.2)."""
    names: List[str] = []
    for i in range(len(cfg.gru_dims)):
        if cfg.scheme == SCHEME_JOINT:
            names.append(f"grujoint{i}")
        elif cfg.scheme == SCHEME_SPLIT:
            for gate in "zrh":
                names.append(f"rec{i}_{gate}")
                names.append(f"nonrec{i}_{gate}")
        else:
            names.append(f"rec{i}")
            names.append(f"nonrec{i}")
    names.append("fc")
    return names


def group_full_shape(cfg: ModelConfig, name: str) -> Tuple[int, int]:
    """Unfactored shape of a named group."""
    if name == "fc":
        return (cfg.fc_dim, cfg.gru_dims[-1])
    base = name.rstrip("zrh").rstrip("_")
    if base.startswith("grujoint"):
        i = int(base[len("grujoint") :])
        h = cfg.gru_dims[i]
        return (3 * h, cfg.gru_input_dim(i) + h)
    # rec{i} / nonrec{i} / rec{i}_g / nonrec{i}_g
    parts = name.split("_")
    kind_i = parts[0]
    per_gate = len(parts) == 2
    if kind_i.startswith("nonrec"):
        i = int(kind_i[len("nonrec") :])
        rows = cfg.gru_dims[i] if per_gate else 3 * cfg.gru_dims[i]
        return (rows, cfg.gru_input_dim(i))
    i = int(kind_i[len("rec") :])
    rows = cfg.gru_dims[i] if per_gate else 3 * cfg.gru_dims[i]
    return (rows, cfg.gru_dims[i])


def is_recurrent_group(name: str) -> bool:
    """Groups regularized with lambda_rec (vs lambda_nonrec).

    Per the paper, reset/update gate weights are grouped with the recurrent
    matrix; the completely-joint matrix acts on [x; h] and is treated as
    recurrent.  fc and nonrec groups take lambda_nonrec.
    """
    return name.startswith("rec") or name.startswith("grujoint")


def apply_group(
    cfg: ModelConfig, params: Params, name: str, x: jnp.ndarray
) -> jnp.ndarray:
    """y = x @ W_name.T under the config's scheme (full or factored)."""
    if cfg.scheme == SCHEME_UNFACTORED or name.startswith("conv") or name == "out":
        w = params[f"{name}_w"]
        if cfg.use_masks and f"{name}_mask" in params:
            w = w * params[f"{name}_mask"]
        return kernels.matmul_t(x, w)
    u = params[f"{name}_u"]
    v = params[f"{name}_v"]
    return kernels.lowrank_apply(x, u, v)


# --------------------------------------------------------------------------
# GRU layers.
# --------------------------------------------------------------------------


def _rec_nonrec_names(cfg: ModelConfig, i: int) -> Tuple[List[str], List[str]]:
    if cfg.scheme == SCHEME_SPLIT:
        return (
            [f"rec{i}_{g}" for g in "zrh"],
            [f"nonrec{i}_{g}" for g in "zrh"],
        )
    return ([f"rec{i}"], [f"nonrec{i}"])


def _apply_many(
    cfg: ModelConfig, params: Params, names: Sequence[str], x: jnp.ndarray
) -> jnp.ndarray:
    """Apply one or three (split-scheme) groups, concatenating gate outputs."""
    outs = [apply_group(cfg, params, n, x) for n in names]
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=-1)


def gru_layer(
    cfg: ModelConfig,
    params: Params,
    i: int,
    x: jnp.ndarray,
    h0: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Forward GRU layer i. x: (B, T, Din), h0: (B, H) -> ((B, T, H), h_T).

    For all schemes except ``joint``, the non-recurrent GEMM is hoisted out
    of the scan and batched across time — the paper's §4 observation that
    ``W x_t`` admits time-batching while ``U h_{t-1}`` is sequential.
    """
    b, t, din = x.shape
    h = cfg.gru_dims[i]
    bias = params[f"gru{i}_b"]  # (3H,)

    if cfg.scheme == SCHEME_JOINT:
        # The joint scheme factors the single (3H, Din+H) matrix over
        # [x; h], but eq. (10) still needs the gx/gh separation for the
        # r * (U_h h) candidate term — so we split V's columns into the x-
        # and h- halves and share U.  The x-half can then still be batched
        # across time.
        name = f"grujoint{i}"
        u = params[f"{name}_u"]
        v = params[f"{name}_v"]
        vx, vh = v[:, :din], v[:, din:]

        gx_all = kernels.lowrank_apply(x.reshape(b * t, din), u, vx) + bias
        gx_all = gx_all.reshape(b, t, 3 * h)

        def step(hprev, gx_t):
            gh = kernels.lowrank_apply(hprev, u, vh)
            hnew = kernels.gru_gates(gx_t, gh, hprev)
            return hnew, hnew

        h_last, hs = lax.scan(step, h0, gx_all.transpose(1, 0, 2))
        return hs.transpose(1, 0, 2), h_last

    rec_names, nonrec_names = _rec_nonrec_names(cfg, i)
    gx_all = _apply_many(cfg, params, nonrec_names, x.reshape(b * t, din)) + bias
    gx_all = gx_all.reshape(b, t, 3 * h)

    def step(hprev, gx_t):
        gh = _apply_many(cfg, params, rec_names, hprev)
        hnew = kernels.gru_gates(gx_t, gh, hprev)
        return hnew, hnew

    h_last, hs = lax.scan(step, h0, gx_all.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2), h_last


def fc_softmax(cfg: ModelConfig, params: Params, x: jnp.ndarray) -> jnp.ndarray:
    """FC (compressible) + ReLU, then output projection + log-softmax.

    x: (B, T, H_last) -> logprobs (B, T, V).
    """
    b, t, d = x.shape
    y = apply_group(cfg, params, "fc", x.reshape(b * t, d)) + params["fc_b"]
    y = jax.nn.relu(y)
    logits = kernels.matmul_t(y, params["out_w"]) + params["out_b"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    return logp.reshape(b, t, cfg.vocab)

"""AOT lowering driver: every model variant -> artifacts/*.hlo.txt + manifest.

Run once at build time (``make artifacts``); Python never appears on the
Rust request path.  Interchange is **HLO text** — the image's
xla_extension 0.5.1 rejects jax>=0.5 serialized protos (64-bit instruction
ids), while the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

The manifest (artifacts/manifest.json) is the L2<->L3 contract: for every
artifact it records the exact input/output ordering (flat, name-sorted
parameters first), shapes and dtypes, so the Rust runtime can marshal
literals without any knowledge of JAX pytrees.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import (
    ALPHABET,
    BASE_CONFIGS,
    EVAL_BATCH,
    RANK_LADDER,
    SCHEME_JOINT,
    SCHEME_PARTIAL,
    SCHEME_SPLIT,
    SCHEME_UNFACTORED,
    STREAM_CHUNKS,
    TRAIN_BATCH,
    BatchSpec,
    ModelConfig,
)

F32 = jnp.float32
S32 = jnp.int32
S8 = jnp.int8

_DTYPE_NAMES = {F32: "f32", S32: "s32", S8: "s8"}


def _spec(shape: Sequence[int], dt=F32) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dt)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


@dataclasses.dataclass
class IoSpec:
    name: str
    shape: Tuple[int, ...]
    dtype: str

    def as_json(self) -> Dict:
        return {"name": self.name, "shape": list(self.shape), "dtype": self.dtype}


class ArtifactWriter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries: List[Dict] = []
        os.makedirs(out_dir, exist_ok=True)

    def add(
        self,
        name: str,
        kind: str,
        cfg: ModelConfig,
        fn,
        in_specs: List[Tuple[str, jax.ShapeDtypeStruct]],
        out_specs: List[IoSpec],
        extra: Optional[Dict] = None,
    ) -> None:
        t0 = time.time()
        lowered = jax.jit(fn).lower(*[s for _, s in in_specs])
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        entry = {
            "name": name,
            "file": fname,
            "kind": kind,
            "config": cfg.name,
            "scheme": cfg.scheme,
            "rank_frac": cfg.rank_frac,
            "use_masks": cfg.use_masks,
            "inputs": [
                IoSpec(n, tuple(s.shape), _dt_name(s.dtype)).as_json()
                for n, s in in_specs
            ],
            "outputs": [o.as_json() for o in out_specs],
        }
        if extra:
            entry.update(extra)
        self.entries.append(entry)
        print(f"  {name}: {len(text)} chars in {time.time() - t0:.1f}s", flush=True)


def _dt_name(dt) -> str:
    return {"float32": "f32", "int32": "s32", "int8": "s8"}[jnp.dtype(dt).name]


# --------------------------------------------------------------------------
# Per-kind artifact builders.
# --------------------------------------------------------------------------


def build_train(w: ArtifactWriter, cfg: ModelConfig, bs: BatchSpec, name: str) -> None:
    shapes = M.param_shapes(cfg)
    pnames = sorted(shapes)
    mnames = M.mask_names(cfg)

    in_specs: List[Tuple[str, jax.ShapeDtypeStruct]] = []
    in_specs += [(n, _spec(shapes[n])) for n in pnames]
    in_specs += [(f"mom:{n}", _spec(shapes[n])) for n in pnames]
    in_specs += [
        (mn, _spec(shapes[mn.removesuffix("_mask") + "_w"])) for mn in mnames
    ]
    in_specs += [
        ("feats", _spec((bs.batch, bs.max_frames, cfg.feat_dim))),
        ("frame_lens", _spec((bs.batch,), S32)),
        ("labels", _spec((bs.batch, bs.max_label), S32)),
        ("label_lens", _spec((bs.batch,), S32)),
        ("lr", _spec(())),
        ("lam_rec", _spec(())),
        ("lam_nonrec", _spec(())),
    ]

    np_, nm = len(pnames), len(mnames)

    def fn(*args):
        params = dict(zip(pnames, args[:np_]))
        mom = dict(zip(pnames, args[np_ : 2 * np_]))
        params.update(dict(zip(mnames, args[2 * np_ : 2 * np_ + nm])))
        feats, fl, labels, ll, lr, lrec, lnon = args[2 * np_ + nm :]
        p2, m2, met = M.train_step(cfg, params, mom, feats, fl, labels, ll, lr, lrec, lnon)
        return (
            tuple(p2[n] for n in pnames)
            + tuple(m2[n] for n in pnames)
            + (met["loss"], met["ctc"], met["penalty"], met["grad_norm"])
        )

    out_specs = (
        [IoSpec(n, shapes[n], "f32") for n in pnames]
        + [IoSpec(f"mom:{n}", shapes[n], "f32") for n in pnames]
        + [
            IoSpec("loss", (), "f32"),
            IoSpec("ctc", (), "f32"),
            IoSpec("penalty", (), "f32"),
            IoSpec("grad_norm", (), "f32"),
        ]
    )
    w.add(
        name,
        "train",
        cfg,
        fn,
        in_specs,
        out_specs,
        extra={
            "param_names": pnames,
            "mask_names": mnames,
            "batch": dataclasses.asdict(bs),
        },
    )


def build_eval(w: ArtifactWriter, cfg: ModelConfig, bs: BatchSpec, name: str) -> None:
    shapes = M.param_shapes(cfg)
    pnames = sorted(shapes)
    tout = bs.max_frames // cfg.total_stride
    in_specs = [(n, _spec(shapes[n])) for n in pnames] + [
        ("feats", _spec((bs.batch, bs.max_frames, cfg.feat_dim))),
        ("frame_lens", _spec((bs.batch,), S32)),
    ]

    def fn(*args):
        params = dict(zip(pnames, args[: len(pnames)]))
        feats, fl = args[len(pnames) :]
        logp, out_lens = M.forward(cfg, params, feats, fl)
        return (logp, out_lens)

    out_specs = [
        IoSpec("logprobs", (bs.batch, tout, cfg.vocab), "f32"),
        IoSpec("out_lens", (bs.batch,), "s32"),
    ]
    w.add(
        name,
        "eval",
        cfg,
        fn,
        in_specs,
        out_specs,
        extra={"param_names": pnames, "batch": dataclasses.asdict(bs)},
    )


def build_stream(
    w: ArtifactWriter, cfg: ModelConfig, chunk: int, name: str, int8: bool = False
) -> None:
    tout = chunk // cfg.total_stride
    assert tout >= 1, (chunk, cfg.total_stride)
    if int8:
        shapes = dict(M.param_shapes(cfg))
        qnames = M.quantized_param_names(cfg)
        wire: Dict[str, Tuple[Tuple[int, ...], object]] = {}
        for n, s in shapes.items():
            if n in qnames:
                wire[f"{n}_q"] = (s, S8)
                wire[f"{n}_scale"] = ((), F32)
            else:
                wire[n] = (s, F32)
        pnames = sorted(wire)
        in_specs = [(n, _spec(*wire[n])) for n in pnames]
    else:
        shapes = M.param_shapes(cfg)
        pnames = sorted(shapes)
        in_specs = [(n, _spec(shapes[n])) for n in pnames]
    in_specs += [(f"h{i}", _spec((1, h))) for i, h in enumerate(cfg.gru_dims)]
    in_specs += [("chunk", _spec((1, chunk, cfg.feat_dim)))]
    ngru = len(cfg.gru_dims)

    def fn(*args):
        params = dict(zip(pnames, args[: len(pnames)]))
        hs = list(args[len(pnames) : len(pnames) + ngru])
        chunk_x = args[len(pnames) + ngru]
        step = M.stream_step_int8 if int8 else M.stream_step
        new_hs, logp = step(cfg, params, hs, chunk_x)
        return tuple(new_hs) + (logp,)

    out_specs = [
        IoSpec(f"h{i}", (1, h), "f32") for i, h in enumerate(cfg.gru_dims)
    ] + [IoSpec("logprobs", (1, tout, cfg.vocab), "f32")]
    w.add(
        name,
        "stream_int8" if int8 else "stream",
        cfg,
        fn,
        in_specs,
        out_specs,
        extra={"param_names": pnames, "chunk": chunk},
    )


# --------------------------------------------------------------------------
# The artifact set.
# --------------------------------------------------------------------------


def variant(cfg: ModelConfig, scheme: str, frac: Optional[float] = None, masks=False) -> ModelConfig:
    return dataclasses.replace(cfg, scheme=scheme, rank_frac=frac, use_masks=masks)


def frac_tag(frac: Optional[float]) -> str:
    return "full" if frac is None else f"r{int(round(frac * 1000)):03d}"


def build_all(out_dir: str, include_paper: bool) -> None:
    w = ArtifactWriter(out_dir)
    mini = BASE_CONFIGS["wsj_mini"]
    fast = BASE_CONFIGS["wsj_mini_fast"]

    print("[aot] train artifacts")
    build_train(w, variant(mini, SCHEME_UNFACTORED), TRAIN_BATCH, "train_mini_unfact")
    build_train(
        w, variant(mini, SCHEME_UNFACTORED, masks=True), TRAIN_BATCH, "train_mini_unfact_masked"
    )
    build_train(w, variant(mini, SCHEME_PARTIAL), TRAIN_BATCH, "train_mini_partial_full")
    for frac in RANK_LADDER:
        build_train(
            w,
            variant(mini, SCHEME_PARTIAL, frac),
            TRAIN_BATCH,
            f"train_mini_partial_{frac_tag(frac)}",
        )
    build_train(w, variant(mini, SCHEME_SPLIT), TRAIN_BATCH, "train_mini_split_full")
    for frac in (0.25, 0.5):
        build_train(
            w,
            variant(mini, SCHEME_SPLIT, frac),
            TRAIN_BATCH,
            f"train_mini_split_{frac_tag(frac)}",
        )
    build_train(w, variant(mini, SCHEME_JOINT), TRAIN_BATCH, "train_mini_joint_full")
    build_train(w, variant(fast, SCHEME_PARTIAL), TRAIN_BATCH, "train_fast_partial_full")
    for frac in (0.25, 0.5):
        build_train(
            w,
            variant(fast, SCHEME_PARTIAL, frac),
            TRAIN_BATCH,
            f"train_fast_partial_{frac_tag(frac)}",
        )
    # width-scaled dense baselines (Fig. 8)
    for scaled_name in ("wsj_mini_s75", "wsj_mini_s50"):
        scaled = BASE_CONFIGS[scaled_name]
        tag = scaled_name.rsplit("_", 1)[1]
        build_train(
            w, variant(scaled, SCHEME_UNFACTORED), TRAIN_BATCH, f"train_{tag}_unfact"
        )

    print("[aot] eval artifacts")
    build_eval(w, variant(mini, SCHEME_UNFACTORED), EVAL_BATCH, "eval_mini_unfact")
    build_eval(w, variant(mini, SCHEME_PARTIAL), EVAL_BATCH, "eval_mini_partial_full")
    for frac in RANK_LADDER:
        build_eval(
            w,
            variant(mini, SCHEME_PARTIAL, frac),
            EVAL_BATCH,
            f"eval_mini_partial_{frac_tag(frac)}",
        )
    build_eval(w, variant(mini, SCHEME_SPLIT), EVAL_BATCH, "eval_mini_split_full")
    for frac in (0.25, 0.5):
        build_eval(
            w,
            variant(mini, SCHEME_SPLIT, frac),
            EVAL_BATCH,
            f"eval_mini_split_{frac_tag(frac)}",
        )
    build_eval(w, variant(mini, SCHEME_JOINT), EVAL_BATCH, "eval_mini_joint_full")
    build_eval(w, variant(fast, SCHEME_PARTIAL), EVAL_BATCH, "eval_fast_partial_full")
    for frac in (0.25, 0.5):
        build_eval(
            w,
            variant(fast, SCHEME_PARTIAL, frac),
            EVAL_BATCH,
            f"eval_fast_partial_{frac_tag(frac)}",
        )
    for scaled_name in ("wsj_mini_s75", "wsj_mini_s50"):
        scaled = BASE_CONFIGS[scaled_name]
        tag = scaled_name.rsplit("_", 1)[1]
        build_eval(
            w, variant(scaled, SCHEME_UNFACTORED), EVAL_BATCH, f"eval_{tag}_unfact"
        )

    print("[aot] stream artifacts")
    for chunk in STREAM_CHUNKS:
        build_stream(
            w, variant(mini, SCHEME_PARTIAL, 0.25), chunk, f"stream_mini_partial_r250_c{chunk}"
        )
    build_stream(w, variant(mini, SCHEME_UNFACTORED), 8, "stream_mini_unfact_c8")
    build_stream(
        w, variant(mini, SCHEME_PARTIAL, 0.25), 8, "stream_mini_partial_r250_c8_int8", int8=True
    )

    if include_paper:
        print("[aot] paper-dimension shape check (eval only)")
        build_eval(
            w, variant(BASE_CONFIGS["paper"], SCHEME_PARTIAL, 0.25), EVAL_BATCH, "eval_paper_partial_r250"
        )

    manifest = {
        "version": 1,
        "alphabet": ALPHABET,
        "configs": {
            name: {
                "feat_dim": c.feat_dim,
                "conv": [{"context": s.context, "dim": s.dim} for s in c.conv],
                "gru_dims": list(c.gru_dims),
                "fc_dim": c.fc_dim,
                "vocab": c.vocab,
                "total_stride": c.total_stride,
            }
            for name, c in BASE_CONFIGS.items()
        },
        "rank_ladder": list(RANK_LADDER),
        "artifacts": w.entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(w.entries)} artifacts + manifest to {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--paper", action="store_true", help="also lower paper-dim eval")
    args = ap.parse_args()
    build_all(args.out, args.paper)


if __name__ == "__main__":
    main()

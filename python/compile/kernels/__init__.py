"""L1 Pallas kernels (build-time only; lowered into the model HLO).

Public surface:
  matmul_t       y = x @ W.T                (tiled Pallas GEMM)
  lowrank_apply  y = x @ (U V).T            (factored GEMM, the paper's §3 op)
  gru_gates      fused GRU gate nonlinearity (paper eq. (10))
  int8_gemm      quantized GEMM              (TPU model of the §4 farm kernel)
  ref            pure-jnp oracles for all of the above
"""

from .matmul import matmul_t, lowrank_apply
from .gru_gates import gru_gates
from .int8_gemm import int8_gemm
from . import ref

__all__ = ["matmul_t", "lowrank_apply", "gru_gates", "int8_gemm", "ref"]

"""L1 Pallas kernel: fused GRU gate nonlinearity (paper eq. (10)).

After the two GEMMs of a GRU step (non-recurrent ``gx`` — batchable across
time — and recurrent ``gh`` — strictly sequential), the remaining work is
elementwise: two sigmoids, a tanh and the convex combination.  Fusing them
into one kernel means the (B, 3H) gate pre-activations are read from VMEM
exactly once and ``h`` is updated in a single pass — on a real TPU this is
a VPU-only kernel with zero HBM round-trips for intermediates.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gru_gates_kernel(gx_ref, gh_ref, h_ref, o_ref):
    """Single-block fused gate computation.

    Blocks are (bm, 3H) / (bm, H): the hidden dimension is kept whole so
    the z/r/h~ split is static slicing inside the kernel.
    """
    h = h_ref[...]
    hdim = h.shape[-1]
    gx = gx_ref[...]
    gh = gh_ref[...]
    z = jax.nn.sigmoid(gx[:, :hdim] + gh[:, :hdim])
    r = jax.nn.sigmoid(gx[:, hdim : 2 * hdim] + gh[:, hdim : 2 * hdim])
    htl = jnp.tanh(gx[:, 2 * hdim :] + r * gh[:, 2 * hdim :])
    o_ref[...] = (1.0 - z) * h + z * htl


def _gru_gates_raw(
    gx: jnp.ndarray, gh: jnp.ndarray, h: jnp.ndarray, *, bm: int = 8
) -> jnp.ndarray:
    """Fused ``h' = GRUGates(gx, gh, h)``.

    gx, gh: (B, 3H); h: (B, H) -> h': (B, H).  The batch dimension is
    gridded in blocks of ``bm`` rows; H stays whole (it is ≤ 1280 even at
    paper scale, i.e. ≤ 15 KB of VMEM per operand row block).
    """
    b, hdim = h.shape
    assert gx.shape == (b, 3 * hdim) and gh.shape == (b, 3 * hdim), (
        gx.shape,
        gh.shape,
        h.shape,
    )
    bm = min(bm, b)
    if b % bm != 0:
        pad = (-b) % bm
        gx = jnp.pad(gx, ((0, pad), (0, 0)))
        gh = jnp.pad(gh, ((0, pad), (0, 0)))
        h = jnp.pad(h, ((0, pad), (0, 0)))
    bp = h.shape[0]
    out = pl.pallas_call(
        _gru_gates_kernel,
        grid=(bp // bm,),
        in_specs=[
            pl.BlockSpec((bm, 3 * hdim), lambda i: (i, 0)),
            pl.BlockSpec((bm, 3 * hdim), lambda i: (i, 0)),
            pl.BlockSpec((bm, hdim), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, hdim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, hdim), jnp.float32),
        interpret=True,
    )(gx, gh, h)
    return out[:b]


# pallas_call lacks an AD rule for this kernel shape, so the backward pass
# is derived from the pure-jnp oracle (mathematically identical, and the
# gate residuals are recomputed rather than stored — rematerialization is
# the right trade for a (B, 3H) elementwise op).
@jax.custom_vjp
def gru_gates(gx: jnp.ndarray, gh: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Fused ``h' = GRUGates(gx, gh, h)`` (Pallas kernel, differentiable)."""
    return _gru_gates_raw(gx, gh, h)


def _gru_gates_fwd(gx, gh, h):
    return _gru_gates_raw(gx, gh, h), (gx, gh, h)


def _gru_gates_bwd(res, dh_out):
    from . import ref

    _, vjp = jax.vjp(ref.gru_gates_ref, *res)
    return vjp(dh_out)


gru_gates.defvjp(_gru_gates_fwd, _gru_gates_bwd)

"""L1 Pallas kernel: int8 x int8 -> int32 quantized GEMM ("farm" model).

This is the TPU-side model of the paper's §4 contribution: a GEMM for the
low-batch regime (batch 1–4) on 8-bit weights.  The paper's farm kernels
beat gemmlowp 3–7x at batch ≤ 4 because they skip the pack/unpack pipeline
and stream the big operand once, bandwidth-bound.  The Pallas expression of
the same idea:

  * the quantized activation panel (m ≤ 8 rows) is the stationary operand;
  * weight blocks stream through VMEM and are consumed in int32
    multiply-accumulate (the MXU's native int8 path on TPU);
  * dequantization happens once per output tile, on the final k step —
    no intermediate f32 traffic.

interpret=True for CPU-PJRT execution (see matmul.py docstring).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _int8_gemm_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, *, nk: int):
    """Accumulate int32 partial products; dequantize on the last k step.

    The output tile doubles as the accumulator (f32 holds int32 exactly up
    to 2^24; with k ≤ 8192 and |q| ≤ 127 the accumulated magnitude stays
    ≤ k·127² < 2^24 for the shapes used here, and the f32 tile is written
    back exactly).  To stay exact for any k we accumulate in f32 *scaled*
    only at the end.
    """
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = jnp.dot(
        x_ref[...].astype(jnp.int32),
        w_ref[...].astype(jnp.int32).T,
        preferred_element_type=jnp.int32,
    )
    o_ref[...] += acc.astype(jnp.float32)

    @pl.when(kk == nk - 1)
    def _dequant():
        o_ref[...] *= sx_ref[0] * sw_ref[0]


def int8_gemm(
    xq: jnp.ndarray,
    wq: jnp.ndarray,
    x_scale: jnp.ndarray,
    w_scale: jnp.ndarray,
    *,
    bm: int = 8,
    bn: int = 128,
    bk: int = 256,
) -> jnp.ndarray:
    """Dequantized ``y = (x_scale*xq) @ (w_scale*wq).T``.

    xq: (m, k) int8, wq: (n, k) int8, scales: scalar f32 arrays (shape
    (1,)).  Returns f32 (m, n).
    """
    m, k = xq.shape
    n, k2 = wq.shape
    assert k == k2
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k)

    def pad(a, axis, mult):
        rem = (-a.shape[axis]) % mult
        if rem == 0:
            return a
        pads = [(0, 0)] * a.ndim
        pads[axis] = (0, rem)
        return jnp.pad(a, pads)

    xp = pad(pad(xq, 0, bm), 1, bk)
    wp = pad(pad(wq, 0, bn), 1, bk)
    mp, kp = xp.shape
    np_, _ = wp.shape
    nk = kp // bk
    x_scale = jnp.asarray(x_scale, jnp.float32).reshape((1,))
    w_scale = jnp.asarray(w_scale, jnp.float32).reshape((1,))
    out = pl.pallas_call(
        functools.partial(_int8_gemm_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((1,), lambda i, j, kk: (0,)),
            pl.BlockSpec((1,), lambda i, j, kk: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, x_scale, w_scale)
    return out[:m, :n]

"""L1 Pallas kernels: tiled transposed matmul and the fused low-rank apply.

These are the GEMM hot spots of the paper's acoustic model.  The paper's
farm kernels solve the *small-batch* GEMM problem on ARM NEON; the TPU
rethink here (see DESIGN.md §Hardware-Adaptation) expresses the same
HBM↔VMEM data movement with Pallas BlockSpecs:

  * the activation panel ``x`` (batch ≤ 8 rows in the streaming regime) is
    small enough to stay resident in VMEM across the whole grid — the
    analog of farm keeping the batch panel pinned in NEON registers;
  * the weight matrix streams through VMEM in (bn, bk) blocks, and each
    block is fully consumed against the resident activations — the MXU is
    fed from a stationary narrow operand.

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode (which traces to plain HLO)
is the correctness path; TPU efficiency is estimated analytically in
EXPERIMENTS.md §Perf from the block shapes chosen here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shapes. On a real TPU these would be multiples of the
# (8, 128) f32 tile; interpret mode has no such constraint but we keep
# MXU-friendly shapes so the §Perf VMEM/MXU estimates reflect the real
# schedule.
DEF_BM = 8
DEF_BN = 128
DEF_BK = 128


def _ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    """Zero-pad ``x`` along ``axis`` up to a multiple of ``mult``."""
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


def _matmul_t_kernel(x_ref, w_ref, o_ref):
    """One (bm, bn) output tile; grid = (m/bm, n/bn, k/bk), k innermost.

    The output tile is revisited across the k grid dimension (its index_map
    ignores ``kk``), so we initialize it on the first k step and accumulate
    partial products in place — the revolving-accumulator matmul schedule.
    """
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32).T,
        preferred_element_type=jnp.float32,
    )


def _matmul_t_raw(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    bm: int = DEF_BM,
    bn: int = DEF_BN,
    bk: int = DEF_BK,
) -> jnp.ndarray:
    """``y = x @ w.T`` via the tiled Pallas kernel (no AD rule).

    x: (m, k), w: (n, k) -> y: (m, n), f32.  Inputs are zero-padded up to
    block multiples (zero rows/cols contribute nothing) and the result is
    sliced back, so arbitrary shapes are accepted.
    """
    m, k = x.shape
    n, k2 = w.shape
    assert k == k2, f"contraction mismatch: {x.shape} vs {w.shape}"
    bm = min(bm, _ceil_mult(m, 8))
    bn = min(bn, _ceil_mult(n, 8))
    bk = min(bk, _ceil_mult(k, 8))
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bn), 1, bk)
    mp, kp = xp.shape
    np_, _ = wp.shape
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _matmul_t_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


# ``pallas_call`` has no JVP rule for the revolving-accumulator schedule
# (program_id inside the kernel), so we attach the analytic GEMM gradients
# ourselves — expressed through the same Pallas kernel, so the *backward*
# pass of the lowered training HLO also runs the L1 schedule:
#   y = x @ W.T   =>   dx = dy @ W = matmul_t(dy, W.T)
#                      dW = dy.T @ x = matmul_t(dy.T, x.T)
@jax.custom_vjp
def matmul_t(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """``y = x @ w.T`` (Pallas kernel, differentiable)."""
    return _matmul_t_raw(x, w)


def _matmul_t_fwd(x, w):
    return _matmul_t_raw(x, w), (x, w)


def _matmul_t_bwd(res, dy):
    x, w = res
    dx = _matmul_t_raw(dy, w.T)
    dw = _matmul_t_raw(dy.T, x.T)
    return dx, dw


matmul_t.defvjp(_matmul_t_fwd, _matmul_t_bwd)


def lowrank_apply(x: jnp.ndarray, u: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """``y = x @ (U V).T`` as two chained Pallas GEMMs.

    x: (m, k), v: (r, k), u: (n, r) -> (m, n).

    The rank-r bottleneck ``t = x @ V.T`` is (m, r) — for the paper's
    streaming regime m ≤ 8 this is a few KB and stays in VMEM between the
    two kernels (XLA fuses the pad/slice glue); total FLOPs drop from
    ``2·m·n·k`` to ``2·m·r·(n + k)``, the factored-GEMM saving that the
    whole paper is built around.
    """
    t = matmul_t(x, v)
    return matmul_t(t, u)

"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
asserts allclose between the Pallas (interpret=True) output and these
oracles over shape/dtype sweeps (see python/tests/test_kernels.py).

Conventions (shared with layers.py and the Rust engine):
  * activations are row-major ``(batch, features)``
  * weight matrices are ``(n_out, n_in)`` and applied as ``y = x @ W.T``
  * a low-rank factored weight is ``W = U @ V`` with ``U: (n_out, r)``,
    ``V: (r, n_in)``, so ``y = (x @ V.T) @ U.T``
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import nn


def matmul_t_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """y = x @ W.T  with x: (m, k), w: (n, k) -> (m, n), f32 accumulate."""
    return jnp.dot(
        x.astype(jnp.float32),
        w.astype(jnp.float32).T,
        precision="highest",
    )


def lowrank_apply_ref(x: jnp.ndarray, u: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """y = x @ (U V).T = (x @ V.T) @ U.T.

    x: (m, k), v: (r, k), u: (n, r) -> (m, n).
    """
    return matmul_t_ref(matmul_t_ref(x, v), u)


def gru_gates_ref(
    gx: jnp.ndarray, gh: jnp.ndarray, h: jnp.ndarray
) -> jnp.ndarray:
    """Fused GRU gate nonlinearity (paper eq. (10)).

    gx = x_t @ [W_z; W_r; W_h].T + b   -- shape (B, 3H)
    gh = h_{t-1} @ [U_z; U_r; U_h].T   -- shape (B, 3H)
    h  = h_{t-1}                       -- shape (B, H)

    z   = sigmoid(gx_z + gh_z)
    r   = sigmoid(gx_r + gh_r)
    htl = tanh(gx_h + r * gh_h)
    h'  = (1 - z) * h + z * htl
    """
    hdim = h.shape[-1]
    z = nn.sigmoid(gx[..., :hdim] + gh[..., :hdim])
    r = nn.sigmoid(gx[..., hdim : 2 * hdim] + gh[..., hdim : 2 * hdim])
    htl = jnp.tanh(gx[..., 2 * hdim :] + r * gh[..., 2 * hdim :])
    return (1.0 - z) * h + z * htl


def int8_gemm_ref(
    xq: jnp.ndarray,
    wq: jnp.ndarray,
    x_scale: jnp.ndarray,
    w_scale: jnp.ndarray,
) -> jnp.ndarray:
    """Quantized GEMM oracle: int8 x int8 -> int32 accumulate -> f32 dequant.

    xq: (m, k) int8, wq: (n, k) int8; symmetric per-tensor scales.
    y[i, j] = x_scale * w_scale * sum_k xq[i, k] * wq[j, k]
    """
    acc = jnp.dot(
        xq.astype(jnp.int32), wq.astype(jnp.int32).T, preferred_element_type=jnp.int32
    )
    return acc.astype(jnp.float32) * (x_scale * w_scale)

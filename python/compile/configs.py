"""Model configurations shared by the L2 graph and (via manifest.json) L3.

The paper's baseline is a forward-only Deep Speech 2: conv frontend, three
forward GRU layers with *growing* dimensions (App. B.1: 768/1024/1280, FC
1536), CTC loss over characters.  ``wsj_mini`` scales every dimension by
1/8 so the whole experiment suite runs on a single CPU core; ``paper``
keeps the published dimensions (used for shape checks and kernel-schedule
estimates, not for training on this box).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

# CTC alphabet: blank + space + apostrophe + a..z  (29 symbols).
BLANK = 0
ALPHABET = ["<b>", " ", "'"] + [chr(ord("a") + i) for i in range(26)]
VOCAB = len(ALPHABET)

# Weight-group names.  The paper's partially-joint factorization (App. B.2)
# concatenates the 3 recurrent matrices of each GRU into one ``rec`` matrix
# (3H, H) and the 3 non-recurrent ones into one ``nonrec`` matrix (3H, Din).
REC = "rec"
NONREC = "nonrec"

SCHEME_UNFACTORED = "unfactored"
SCHEME_JOINT = "joint"  # completely joint: one (3H, Din+H) matrix per GRU
SCHEME_PARTIAL = "partial"  # paper's choice: rec and nonrec factored separately
SCHEME_SPLIT = "split"  # completely split: 6 matrices per GRU factored alone


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One frontend layer: stack ``context`` consecutive frames (stride =
    context, non-overlapping) and project to ``dim`` with ReLU.

    Non-overlapping stacking keeps streaming chunk-exact: a chunk whose
    length is a multiple of the total stride needs no cross-chunk context.
    """

    context: int
    dim: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    feat_dim: int
    conv: Tuple[ConvSpec, ...]
    gru_dims: Tuple[int, ...]
    fc_dim: int
    vocab: int = VOCAB
    # Low-rank scheme + per-group ranks. rank None => full min(m, n).
    scheme: str = SCHEME_UNFACTORED
    # rank fraction of min(m, n), quantized to a multiple of 4 per group.
    rank_frac: Optional[float] = None
    use_masks: bool = False  # weight-mask inputs (sparsity baseline, Fig 8)

    @property
    def total_stride(self) -> int:
        s = 1
        for c in self.conv:
            s *= c.context
        return s

    def gru_input_dim(self, layer: int) -> int:
        return self.conv[-1].dim if layer == 0 else self.gru_dims[layer - 1]

    def group_shape(self, name: str) -> Tuple[int, int]:
        """Full (unfactored) shape of a named weight group."""
        kind, idx = name.rsplit("_", 1)
        if kind in ("rec", "nonrec", "grujoint"):
            i = int(idx)
            h = self.gru_dims[i]
            din = self.gru_input_dim(i)
            if kind == "rec":
                return (3 * h, h)
            if kind == "nonrec":
                return (3 * h, din)
            return (3 * h, din + h)
        raise ValueError(name)

    def rank_of(self, full: Tuple[int, int]) -> int:
        m, n = full
        r_full = min(m, n)
        if self.rank_frac is None:
            return r_full
        r = max(4, int(round(self.rank_frac * r_full / 4)) * 4)
        return min(r, r_full)


def _mk(name, feat, conv_dims, gru_dims, fc_dim, **kw) -> ModelConfig:
    conv = tuple(ConvSpec(context=2, dim=d) for d in conv_dims)
    return ModelConfig(
        name=name, feat_dim=feat, conv=conv, gru_dims=tuple(gru_dims), fc_dim=fc_dim, **kw
    )


# --- the two base configs -------------------------------------------------

# 1/8-scale analog of the paper's WSJ model (App. B.1 dims / 8).
WSJ_MINI = _mk("wsj_mini", 40, (64, 96), (96, 128, 160), 192)

# "fast" variant = tier-3 / Gram-CTC analog (App. B.4): one extra stride-2
# stage (wider to compensate), halving GRU sequence length.
WSJ_MINI_FAST = _mk("wsj_mini_fast", 40, (64, 96, 128), (96, 128, 160), 192)

# Width-scaled dense baselines for Fig. 8 (the paper compares low-rank
# factorization against simply shrinking the GRU dimension).
WSJ_MINI_S75 = _mk("wsj_mini_s75", 40, (64, 96), (72, 96, 120), 144)
WSJ_MINI_S50 = _mk("wsj_mini_s50", 40, (64, 96), (48, 64, 80), 96)

# Published dimensions (shape-check / schedule-estimate only on this box).
PAPER = _mk("paper", 161, (512, 512), (768, 1024, 1280), 1536)

BASE_CONFIGS = {
    c.name: c for c in [WSJ_MINI, WSJ_MINI_FAST, WSJ_MINI_S75, WSJ_MINI_S50, PAPER]
}

# --- training batch geometry (static shapes for AOT) ----------------------


@dataclasses.dataclass(frozen=True)
class BatchSpec:
    batch: int
    max_frames: int  # raw feature frames (pre-frontend)
    max_label: int

    def out_frames(self, cfg: ModelConfig) -> int:
        return self.max_frames // cfg.total_stride


# max_label is bounded by the post-frontend sequence length: stride 4 =>
# 32 GRU steps for 128 raw frames, and CTC needs >= label_len + repeats
# steps (stride-8 "fast" config: 16 steps), so 12 is the safe ceiling.
TRAIN_BATCH = BatchSpec(batch=8, max_frames=128, max_label=12)
EVAL_BATCH = BatchSpec(batch=8, max_frames=128, max_label=12)
STREAM_CHUNKS = (4, 8, 16)  # raw frames per streaming chunk (multiples of stride)

# Stage-2 rank ladder (fractions of full rank per group). aot.py lowers one
# train+eval artifact per rung; the Rust warmstart picks the smallest rung
# whose rank >= the explained-variance rank (DESIGN.md §8).
RANK_LADDER = (0.125, 0.25, 0.375, 0.5, 0.75)

"""Connectionist Temporal Classification loss (log domain, lax.scan).

Stand-alone, mask-correct implementation supporting padded batches with
variable frame and label lengths — the substrate the paper's training
pipeline depends on (Deep Speech 2 is a CTC model).

Conventions: blank index 0; ``labels`` padded with 0 beyond
``label_lens``; extended label sequence ext = [b, l1, b, l2, ..., lL, b] of
static length S = 2·Lmax + 1.

Tested against brute-force alignment enumeration in
python/tests/test_ctc.py.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1.0e30


def extend_labels(labels: jnp.ndarray) -> jnp.ndarray:
    """(B, L) -> (B, 2L+1) blank-interleaved extended labels."""
    b, l = labels.shape
    ext = jnp.zeros((b, 2 * l + 1), dtype=labels.dtype)
    return ext.at[:, 1::2].set(labels)


def ctc_loss(
    logprobs: jnp.ndarray,
    frame_lens: jnp.ndarray,
    labels: jnp.ndarray,
    label_lens: jnp.ndarray,
) -> jnp.ndarray:
    """Per-utterance negative log likelihood.

    logprobs: (B, T, V) log-softmax outputs; frame_lens: (B,) valid frame
    counts; labels: (B, L) with 0-padding; label_lens: (B,).
    Returns nll: (B,).
    """
    b, t, v = logprobs.shape
    l = labels.shape[1]
    s = 2 * l + 1

    ext = extend_labels(labels)  # (B, S)
    # Positions where a skip transition (s-2 -> s) is allowed: ext[s] is a
    # real label and differs from ext[s-2].
    ext_shift2 = jnp.concatenate(
        [jnp.full((b, 2), -1, dtype=ext.dtype), ext[:, :-2]], axis=1
    )
    can_skip = (ext != 0) & (ext != ext_shift2)  # (B, S)

    # alpha_0
    lp0 = logprobs[:, 0, :]  # (B, V)
    alpha0 = jnp.full((b, s), NEG_INF)
    alpha0 = alpha0.at[:, 0].set(jnp.take_along_axis(lp0, ext[:, 0:1], axis=1)[:, 0])
    alpha0 = alpha0.at[:, 1].set(
        jnp.where(
            label_lens > 0,
            jnp.take_along_axis(lp0, ext[:, 1:2], axis=1)[:, 0],
            NEG_INF,
        )
    )

    def step(alpha, inputs):
        lp_t, t_idx = inputs  # lp_t: (B, V)
        prev1 = jnp.concatenate([jnp.full((b, 1), NEG_INF), alpha[:, :-1]], axis=1)
        prev2 = jnp.concatenate([jnp.full((b, 2), NEG_INF), alpha[:, :-2]], axis=1)
        prev2 = jnp.where(can_skip, prev2, NEG_INF)
        stacked = jnp.stack([alpha, prev1, prev2], axis=0)
        merged = jax.scipy.special.logsumexp(stacked, axis=0)
        lp_ext = jnp.take_along_axis(lp_t, ext, axis=1)  # (B, S)
        new_alpha = merged + lp_ext
        # Frames at/after frame_lens are padding: carry alpha unchanged.
        active = (t_idx < frame_lens)[:, None]
        new_alpha = jnp.where(active, new_alpha, alpha)
        return new_alpha, None

    lps = logprobs.transpose(1, 0, 2)[1:]  # (T-1, B, V)
    t_ids = jnp.arange(1, t)
    alpha_last, _ = lax.scan(step, alpha0, (lps, t_ids))

    # Likelihood mass ends at ext positions 2*label_len (final blank) and
    # 2*label_len - 1 (final label).
    end = 2 * label_lens  # (B,)
    a_end = jnp.take_along_axis(alpha_last, end[:, None], axis=1)[:, 0]
    end_m1 = jnp.maximum(end - 1, 0)
    a_end_m1 = jnp.where(
        label_lens > 0,
        jnp.take_along_axis(alpha_last, end_m1[:, None], axis=1)[:, 0],
        NEG_INF,
    )
    ll = jnp.logaddexp(a_end, a_end_m1)
    return -ll


def ctc_loss_mean(
    logprobs: jnp.ndarray,
    frame_lens: jnp.ndarray,
    labels: jnp.ndarray,
    label_lens: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(mean per-character nll, per-utterance nll) — the training loss.

    Normalizing by label length keeps the loss scale comparable across the
    synthetic corpus's variable utterance lengths (cf. DS2 §3).
    """
    nll = ctc_loss(logprobs, frame_lens, labels, label_lens)
    denom = jnp.maximum(label_lens.astype(jnp.float32), 1.0)
    return jnp.mean(nll / denom), nll

"""Layer-level tests: frame stacking, group shapes, scheme algebra."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import configs, layers, model
from compile.configs import (
    SCHEME_JOINT,
    SCHEME_PARTIAL,
    SCHEME_SPLIT,
    SCHEME_UNFACTORED,
    WSJ_MINI,
)


def test_stack_frames_values():
    x = jnp.arange(2 * 6 * 3, dtype=jnp.float32).reshape(2, 6, 3)
    y = layers.stack_frames(x, 2)
    assert y.shape == (2, 3, 6)
    # first stacked frame = concat of frames 0 and 1
    np.testing.assert_array_equal(
        np.asarray(y[0, 0]), np.concatenate([np.asarray(x[0, 0]), np.asarray(x[0, 1])])
    )


@settings(max_examples=15, deadline=None)
@given(t=st.integers(1, 20), c=st.integers(1, 4))
def test_stack_frames_truncates_ragged(t, c):
    x = jnp.ones((1, t, 2))
    y = layers.stack_frames(x, c)
    assert y.shape == (1, t // c, c * 2)


@pytest.mark.parametrize("scheme", [SCHEME_UNFACTORED, SCHEME_PARTIAL, SCHEME_SPLIT, SCHEME_JOINT])
def test_group_names_cover_four_layers(scheme):
    cfg = dataclasses.replace(WSJ_MINI, scheme=scheme)
    names = layers.group_names(cfg)
    assert "fc" in names
    n_gru_groups = len(names) - 1
    if scheme == SCHEME_JOINT:
        assert n_gru_groups == 3  # one joint group per GRU
    elif scheme == SCHEME_SPLIT:
        assert n_gru_groups == 18  # 6 per GRU
    else:
        assert n_gru_groups == 6  # rec + nonrec per GRU


def test_group_full_shapes_consistent():
    cfg = dataclasses.replace(WSJ_MINI, scheme=SCHEME_PARTIAL)
    assert layers.group_full_shape(cfg, "rec0") == (3 * 96, 96)
    assert layers.group_full_shape(cfg, "nonrec0") == (3 * 96, 96)  # conv out = 96
    assert layers.group_full_shape(cfg, "nonrec1") == (3 * 128, 96)
    assert layers.group_full_shape(cfg, "fc") == (192, 160)
    joint = dataclasses.replace(WSJ_MINI, scheme=SCHEME_JOINT)
    assert layers.group_full_shape(joint, "grujoint1") == (3 * 128, 96 + 128)
    split = dataclasses.replace(WSJ_MINI, scheme=SCHEME_SPLIT)
    assert layers.group_full_shape(split, "rec1_z") == (128, 128)
    assert layers.group_full_shape(split, "nonrec1_h") == (128, 96)


def test_recurrent_group_classification():
    assert layers.is_recurrent_group("rec2")
    assert layers.is_recurrent_group("grujoint0")
    assert not layers.is_recurrent_group("nonrec2")
    assert not layers.is_recurrent_group("fc")


def test_split_matches_partial_when_factors_agree():
    """If split per-gate factors are row-blocks of the partial factors'
    product, both schemes compute the same GRU layer output."""
    cfg_p = dataclasses.replace(
        WSJ_MINI, conv=(configs.ConvSpec(2, 10),), gru_dims=(8,), fc_dim=12,
        feat_dim=6, scheme=SCHEME_PARTIAL,
    )
    cfg_s = dataclasses.replace(cfg_p, scheme=SCHEME_SPLIT)
    pp = model.init_params(cfg_p, 0)
    ps = model.init_params(cfg_s, 0)
    # overwrite split factors so each gate's product equals the partial
    # product's corresponding row block, via full-rank identity trick
    rng = np.random.RandomState(0)
    for kind, k_in in [("rec0", 8), ("nonrec0", 10)]:
        w = np.asarray(pp[f"{kind}_u"]) @ np.asarray(pp[f"{kind}_v"])  # (24, k)
        blocks = np.split(w, 3, axis=0)
        for gate, blk in zip("zrh", blocks):
            h = blk.shape[0]
            r = min(h, k_in)
            u, s, vt = np.linalg.svd(blk, full_matrices=False)
            ps[f"{kind}_{gate}_u"] = jnp.asarray((u * s)[:, :r].astype(np.float32))
            ps[f"{kind}_{gate}_v"] = jnp.asarray(vt[:r].astype(np.float32))
    for shared in ["conv0_w", "conv0_b", "gru0_b", "fc_b", "out_w", "out_b"]:
        ps[shared] = pp[shared]
    ps["fc_u"], ps["fc_v"] = pp["fc_u"], pp["fc_v"]

    feats = jnp.asarray(rng.standard_normal((1, 8, 6)).astype(np.float32))
    fl = jnp.asarray([8], jnp.int32)
    lp_p, _ = model.forward(cfg_p, pp, feats, fl)
    lp_s, _ = model.forward(cfg_s, ps, feats, fl)
    np.testing.assert_allclose(np.asarray(lp_p), np.asarray(lp_s), rtol=2e-3, atol=2e-4)


def test_quantized_param_names_cover_dense_ops():
    cfg = dataclasses.replace(WSJ_MINI, scheme=SCHEME_PARTIAL, rank_frac=0.25)
    names = model.quantized_param_names(cfg)
    assert "conv0_w" in names and "out_w" in names
    assert "rec0_u" in names and "rec0_v" in names
    assert not any(n.endswith("_b") for n in names)

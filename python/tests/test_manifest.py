"""Contract tests for the AOT manifest (the L2<->L3 boundary).

These validate the *existing* artifacts directory when present (fast; no
lowering).  The Rust side re-validates every call at runtime, but catching
a drifted contract here gives a much better error message.
"""

import json
import os

import pytest

from compile import aot, configs, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_alphabet_matches_configs(manifest):
    assert manifest["alphabet"] == configs.ALPHABET
    assert manifest["alphabet"][0] == "<b>"  # CTC blank at index 0


def test_artifact_files_exist(manifest):
    for a in manifest["artifacts"]:
        path = os.path.join(ART, a["file"])
        assert os.path.exists(path), a["file"]
        assert os.path.getsize(path) > 1000


def test_train_artifact_io_contract(manifest):
    arts = {a["name"]: a for a in manifest["artifacts"]}
    a = arts["train_mini_partial_full"]
    pnames = a["param_names"]
    assert pnames == sorted(pnames), "params must be name-sorted"
    ins = [io["name"] for io in a["inputs"]]
    n = len(pnames)
    # wire order: params, momentum, (masks,) batch, scalars
    assert ins[:n] == pnames
    assert ins[n : 2 * n] == [f"mom:{p}" for p in pnames]
    assert ins[-7:] == [
        "feats", "frame_lens", "labels", "label_lens", "lr", "lam_rec", "lam_nonrec",
    ]
    outs = [io["name"] for io in a["outputs"]]
    assert outs[:n] == pnames
    assert outs[-4:] == ["loss", "ctc", "penalty", "grad_norm"]


def test_param_shapes_match_python_schema(manifest):
    arts = {a["name"]: a for a in manifest["artifacts"]}
    a = arts["train_mini_partial_full"]
    cfg = aot.variant(configs.BASE_CONFIGS["wsj_mini"], configs.SCHEME_PARTIAL)
    want = model.param_shapes(cfg)
    got = {io["name"]: tuple(io["shape"]) for io in a["inputs"]}
    for name, shape in want.items():
        assert got[name] == tuple(shape), name


def test_masked_artifact_lists_masks(manifest):
    arts = {a["name"]: a for a in manifest["artifacts"]}
    a = arts["train_mini_unfact_masked"]
    assert a["use_masks"]
    assert len(a["mask_names"]) == 7  # 3 rec + 3 nonrec + fc
    for mn in a["mask_names"]:
        assert mn.endswith("_mask")


def test_rank_ladder_artifacts_exist(manifest):
    names = {a["name"] for a in manifest["artifacts"]}
    for frac in manifest["rank_ladder"]:
        tag = aot.frac_tag(frac)
        assert f"train_mini_partial_{tag}" in names
        assert f"eval_mini_partial_{tag}" in names


def test_stream_artifacts_declare_chunk(manifest):
    for a in manifest["artifacts"]:
        if a["kind"].startswith("stream"):
            assert a["chunk"] is not None
            stride = manifest["configs"][a["config"]]["total_stride"]
            assert a["chunk"] % stride == 0, "chunks must be stride-aligned"


def test_int8_stream_wire_format(manifest):
    arts = {a["name"]: a for a in manifest["artifacts"]}
    a = arts["stream_mini_partial_r250_c8_int8"]
    dtypes = {io["name"]: io["dtype"] for io in a["inputs"]}
    assert dtypes["rec0_u_q"] == "s8"
    assert dtypes["rec0_u_scale"] == "f32"
    assert dtypes["gru0_b"] == "f32"  # biases stay f32


def test_rank_fractions_shrink_factors(manifest):
    arts = {a["name"]: a for a in manifest["artifacts"]}
    full = arts["train_mini_partial_full"]
    low = arts["train_mini_partial_r250"]
    shapes_full = {io["name"]: io["shape"] for io in full["inputs"]}
    shapes_low = {io["name"]: io["shape"] for io in low["inputs"]}
    assert shapes_low["rec2_u"][1] < shapes_full["rec2_u"][1]
    assert shapes_low["rec2_u"][0] == shapes_full["rec2_u"][0]

"""L2 model-level tests: shapes, schemes, the trace-norm surrogate math
(paper Lemma 1), training dynamics, and streaming-vs-full consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model
from compile.configs import (
    SCHEME_JOINT,
    SCHEME_PARTIAL,
    SCHEME_SPLIT,
    SCHEME_UNFACTORED,
    TRAIN_BATCH,
    WSJ_MINI,
)

ALL_SCHEMES = [SCHEME_UNFACTORED, SCHEME_PARTIAL, SCHEME_SPLIT, SCHEME_JOINT]


def cfg_for(scheme, frac=None, masks=False):
    return dataclasses.replace(
        WSJ_MINI, scheme=scheme, rank_frac=frac, use_masks=masks
    )


def tiny_cfg(scheme, frac=None):
    """A very small config for fast exact tests."""
    return dataclasses.replace(
        WSJ_MINI,
        conv=(configs.ConvSpec(2, 16),),
        gru_dims=(12, 16),
        fc_dim=20,
        feat_dim=8,
        scheme=scheme,
        rank_frac=frac,
    )


def fake_batch(cfg, b=2, t=16, seed=0):
    r = np.random.RandomState(seed)
    feats = jnp.asarray(r.standard_normal((b, t, cfg.feat_dim)).astype(np.float32))
    fl = jnp.full((b,), t, jnp.int32)
    labels = jnp.asarray(r.randint(1, cfg.vocab, size=(b, 4)).astype(np.int32))
    ll = jnp.full((b,), 3, jnp.int32)
    return feats, fl, labels, ll


# --------------------------------------------------------------------------
# Shapes and schemes.
# --------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_forward_shapes(scheme):
    cfg = tiny_cfg(scheme, frac=0.5 if scheme != SCHEME_UNFACTORED else None)
    p = model.init_params(cfg, 0)
    feats, fl, _, _ = fake_batch(cfg)
    logp, out_lens = model.forward(cfg, p, feats, fl)
    t_out = 16 // cfg.total_stride
    assert logp.shape == (2, t_out, cfg.vocab)
    assert int(out_lens[0]) == t_out
    # log-softmax rows must normalize
    np.testing.assert_allclose(
        np.exp(np.asarray(logp)).sum(-1), 1.0, rtol=1e-4
    )


def test_factored_full_rank_matches_dense_product():
    """A factored model with U V = W must produce identical logprobs to the
    unfactored model with weight W."""
    cfg_f = tiny_cfg(SCHEME_PARTIAL)
    cfg_d = tiny_cfg(SCHEME_UNFACTORED)
    pf = model.init_params(cfg_f, 0)
    pd = {}
    for k, v in pf.items():
        if k.endswith("_u"):
            base = k[:-2]
            pd[f"{base}_w"] = jnp.asarray(
                np.asarray(pf[f"{base}_u"]) @ np.asarray(pf[f"{base}_v"])
            )
        elif k.endswith("_v"):
            continue
        else:
            pd[k] = v
    feats, fl, _, _ = fake_batch(cfg_f)
    lf, _ = model.forward(cfg_f, pf, feats, fl)
    ld, _ = model.forward(cfg_d, pd, feats, fl)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(ld), rtol=2e-3, atol=2e-4)


def test_param_counts_shrink_with_rank():
    full = sum(
        np.prod(s) for s in model.param_shapes(cfg_for(SCHEME_PARTIAL)).values()
    )
    low = sum(
        np.prod(s) for s in model.param_shapes(cfg_for(SCHEME_PARTIAL, 0.125)).values()
    )
    dense = sum(
        np.prod(s) for s in model.param_shapes(cfg_for(SCHEME_UNFACTORED)).values()
    )
    assert low < dense < full


# --------------------------------------------------------------------------
# Lemma 1: the Frobenius surrogate upper-bounds the trace norm, with
# equality at the SVD split U = Ũ√Σ, V = √ΣṼ*.
# --------------------------------------------------------------------------


def test_lemma1_surrogate_bounds_trace_norm():
    r = np.random.RandomState(0)
    w = r.standard_normal((12, 8)).astype(np.float32)
    trace_norm = np.linalg.svd(w, compute_uv=False).sum()
    for seed in range(5):
        rr = np.random.RandomState(seed + 1)
        # random factorization with U V = W via invertible mixing
        m = rr.standard_normal((8, 8)).astype(np.float32)
        u = w @ np.linalg.inv(m)
        v = m
        assert np.allclose(u @ v, w, atol=1e-4)
        surrogate = 0.5 * ((u**2).sum() + (v**2).sum())
        assert surrogate >= trace_norm - 1e-3

    # equality at the balanced SVD split
    uu, ss, vv = np.linalg.svd(w, full_matrices=False)
    u_bal = uu * np.sqrt(ss)
    v_bal = (np.sqrt(ss)[:, None]) * vv
    surrogate = 0.5 * ((u_bal**2).sum() + (v_bal**2).sum())
    np.testing.assert_allclose(surrogate, trace_norm, rtol=1e-5)


def test_penalty_uses_lambda_split():
    """lam_rec only touches recurrent groups; lam_nonrec the rest."""
    cfg = tiny_cfg(SCHEME_PARTIAL)
    p = model.init_params(cfg, 0)
    pen_rec = float(model.regularization_penalty(cfg, p, jnp.float32(1.0), jnp.float32(0.0)))
    pen_non = float(model.regularization_penalty(cfg, p, jnp.float32(0.0), jnp.float32(1.0)))
    pen_both = float(model.regularization_penalty(cfg, p, jnp.float32(1.0), jnp.float32(1.0)))
    assert pen_rec > 0 and pen_non > 0
    np.testing.assert_allclose(pen_rec + pen_non, pen_both, rtol=1e-5)

    rec_sum = 0.5 * sum(
        float(jnp.sum(p[k] * p[k]))
        for k in p
        if (k.startswith("rec") and (k.endswith("_u") or k.endswith("_v")))
    )
    np.testing.assert_allclose(pen_rec, rec_sum, rtol=1e-5)


# --------------------------------------------------------------------------
# Training dynamics.
# --------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", [SCHEME_UNFACTORED, SCHEME_PARTIAL])
def test_train_step_reduces_loss(scheme):
    cfg = tiny_cfg(scheme)
    p = model.init_params(cfg, 0)
    mom = {k: jnp.zeros_like(v) for k, v in p.items()}
    batch = fake_batch(cfg, b=2, t=16)
    step = jax.jit(lambda p, m: model.train_step(
        cfg, p, m, *batch, jnp.float32(5e-3), jnp.float32(0.0), jnp.float32(0.0)
    ))
    _, _, met0 = step(p, mom)
    for _ in range(12):
        p, mom, met = step(p, mom)
    assert float(met["loss"]) < float(met0["loss"])


def test_train_step_rmsprop_and_clip():
    """First-step RMSProp algebra: v = (1-decay) g², update = lr·g/(√v+eps),
    with g the clipped gradient."""
    cfg = tiny_cfg(SCHEME_PARTIAL)
    p = model.init_params(cfg, 0)
    mom = {k: jnp.zeros_like(v) for k, v in p.items()}
    batch = fake_batch(cfg)
    p2, m2, met = model.train_step(
        cfg, p, mom, *batch, jnp.float32(1e-2), jnp.float32(0.0), jnp.float32(0.0)
    )
    some = "fc_u"
    v = np.asarray(m2[some])
    # recover g from v (first step: v = (1-decay) g², sign from update dir)
    g_mag = np.sqrt(v / (1.0 - model.RMS_DECAY))
    expected_step = 1e-2 * g_mag / (np.sqrt(v) + model.RMS_EPS)
    actual_step = np.abs(np.asarray(p2[some]) - np.asarray(p[some]))
    np.testing.assert_allclose(actual_step, expected_step, rtol=1e-3, atol=1e-7)
    # clipped gradient norm is bounded
    gnorm = float(met["grad_norm"])
    total_g2 = sum(
        float(jnp.sum(m2[k] / (1.0 - model.RMS_DECAY))) for k in m2
    )
    clipped = min(gnorm, model.GRAD_CLIP)
    np.testing.assert_allclose(np.sqrt(total_g2), clipped, rtol=1e-3)


def test_masked_weights_receive_no_update():
    cfg = dataclasses.replace(tiny_cfg(SCHEME_UNFACTORED), use_masks=True)
    p = model.init_params(cfg, 0)
    masks = {}
    r = np.random.RandomState(0)
    from compile.layers import group_names

    for g in group_names(cfg):
        shape = p[f"{g}_w"].shape
        masks[f"{g}_mask"] = jnp.asarray(
            (r.uniform(size=shape) > 0.5).astype(np.float32)
        )
    p_all = dict(p)
    p_all.update(masks)
    mom = {k: jnp.zeros_like(v) for k, v in p.items()}
    batch = fake_batch(cfg)
    p2, _, _ = model.train_step(
        cfg, p_all, mom, *batch, jnp.float32(1e-2), jnp.float32(0.0), jnp.float32(0.0)
    )
    g = group_names(cfg)[0]
    w_before = np.asarray(p[f"{g}_w"])
    w_after = np.asarray(p2[f"{g}_w"])
    mask = np.asarray(masks[f"{g}_mask"])
    # masked-out entries get zero gradient through the forward product
    np.testing.assert_allclose(
        w_after[mask == 0], w_before[mask == 0], atol=1e-7
    )
    assert np.abs(w_after[mask == 1] - w_before[mask == 1]).max() > 0


# --------------------------------------------------------------------------
# Streaming consistency: chunked stream_step == full forward.
# --------------------------------------------------------------------------


def test_stream_matches_forward():
    cfg = tiny_cfg(SCHEME_PARTIAL, frac=0.5)
    p = model.init_params(cfg, 0)
    t = 16
    feats, fl, _, _ = fake_batch(cfg, b=1, t=t)
    full, _ = model.forward(cfg, p, feats, fl)

    chunk = 4
    hs = [jnp.zeros((1, h), jnp.float32) for h in cfg.gru_dims]
    outs = []
    for c0 in range(0, t, chunk):
        hs, logp = model.stream_step(cfg, p, hs, feats[:, c0 : c0 + chunk])
        outs.append(np.asarray(logp))
    streamed = np.concatenate(outs, axis=1)
    np.testing.assert_allclose(streamed, np.asarray(full), rtol=1e-3, atol=1e-4)


def test_stream_int8_close_to_f32():
    """Int8 streaming tracks the f32 path within quantization error."""
    cfg = tiny_cfg(SCHEME_PARTIAL, frac=0.5)
    p = model.init_params(cfg, 0)
    qnames = set(model.quantized_param_names(cfg))
    qp = {}
    for k, v in p.items():
        if k in qnames:
            a = np.asarray(v)
            scale = max(np.abs(a).max(), 1e-8) / 127.0
            qp[f"{k}_q"] = jnp.asarray(
                np.clip(np.round(a / scale), -127, 127).astype(np.int8)
            )
            qp[f"{k}_scale"] = jnp.float32(scale)
        else:
            qp[k] = v
    feats, _, _, _ = fake_batch(cfg, b=1, t=8)
    hs = [jnp.zeros((1, h), jnp.float32) for h in cfg.gru_dims]
    hs_q = list(hs)
    _, lp_f32 = model.stream_step(cfg, p, hs, feats)
    _, lp_int8 = model.stream_step_int8(cfg, qp, hs_q, feats)
    # logprob agreement within quantization noise
    diff = np.abs(np.asarray(lp_f32) - np.asarray(lp_int8)).mean()
    assert diff < 0.15, diff

"""CTC loss against brute-force alignment enumeration.

For tiny (T, L) we enumerate every length-T path over the vocab, keep the
paths that collapse (remove repeats, then blanks) to the label, and sum
their probabilities — the definition of the CTC likelihood.  The scan
implementation must match to near machine precision.
"""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.ctc import ctc_loss, ctc_loss_mean, extend_labels


def collapse(path):
    out = []
    prev = None
    for p in path:
        if p != prev:
            if p != 0:
                out.append(p)
        prev = p
    return tuple(out)


def brute_force_nll(logprobs, label):
    """-log sum_{paths collapsing to label} prod_t p[t, path_t]."""
    t, v = logprobs.shape
    total = -np.inf
    for path in itertools.product(range(v), repeat=t):
        if collapse(path) == tuple(label):
            lp = sum(logprobs[i, c] for i, c in enumerate(path))
            total = np.logaddexp(total, lp)
    return -total


def make_logprobs(rng, t, v):
    x = rng.standard_normal((t, v)).astype(np.float32)
    x = x - np.log(np.exp(x).sum(axis=1, keepdims=True))
    return x


@settings(max_examples=20, deadline=None)
@given(
    t=st.integers(2, 5),
    v=st.integers(2, 4),
    seed=st.integers(0, 2**31 - 1),
    data=st.data(),
)
def test_ctc_matches_brute_force(t, v, seed, data):
    rng = np.random.RandomState(seed)
    lmax = min(t, 3)
    llen = data.draw(st.integers(1, lmax))
    label = data.draw(
        st.lists(st.integers(1, v - 1), min_size=llen, max_size=llen)
    )
    # skip labels that need more frames than available (repeats need blanks)
    need = llen + sum(1 for a, b in zip(label, label[1:]) if a == b)
    if need > t:
        return
    lp = make_logprobs(rng, t, v)
    want = brute_force_nll(lp, label)

    pad_l = 4
    labels = np.zeros((1, pad_l), np.int32)
    labels[0, :llen] = label
    got = ctc_loss(
        jnp.asarray(lp)[None],
        jnp.asarray([t], jnp.int32),
        jnp.asarray(labels),
        jnp.asarray([llen], jnp.int32),
    )
    np.testing.assert_allclose(float(got[0]), want, rtol=1e-4, atol=1e-4)


def test_ctc_frame_padding_is_ignored():
    """Loss must be identical whether pad frames carry junk or zeros."""
    rng = np.random.RandomState(0)
    t_valid, t_pad, v = 4, 3, 5
    lp_valid = make_logprobs(rng, t_valid, v)
    junk = make_logprobs(rng, t_pad, v)
    zeros = np.full((t_pad, v), -np.log(v), np.float32)

    labels = np.array([[1, 2, 0, 0]], np.int32)
    args = lambda pad: (
        jnp.asarray(np.concatenate([lp_valid, pad])[None]),
        jnp.asarray([t_valid], jnp.int32),
        jnp.asarray(labels),
        jnp.asarray([2], jnp.int32),
    )
    a = float(ctc_loss(*args(junk))[0])
    b = float(ctc_loss(*args(zeros))[0])
    np.testing.assert_allclose(a, b, rtol=1e-6)


def test_ctc_batch_matches_individual():
    rng = np.random.RandomState(1)
    t, v, l = 6, 5, 3
    lps = [make_logprobs(rng, t, v) for _ in range(3)]
    labels = np.array([[1, 0, 0], [2, 3, 0], [4, 4, 1]], np.int32)
    lens = np.array([1, 2, 3], np.int32)
    batched = ctc_loss(
        jnp.asarray(np.stack(lps)),
        jnp.asarray([t, t, t], jnp.int32),
        jnp.asarray(labels),
        jnp.asarray(lens),
    )
    for i in range(3):
        single = ctc_loss(
            jnp.asarray(lps[i])[None],
            jnp.asarray([t], jnp.int32),
            jnp.asarray(labels[i : i + 1]),
            jnp.asarray(lens[i : i + 1]),
        )
        np.testing.assert_allclose(float(batched[i]), float(single[0]), rtol=1e-5)


def test_extend_labels():
    labels = jnp.asarray([[3, 5, 0]], jnp.int32)
    ext = np.asarray(extend_labels(labels))
    np.testing.assert_array_equal(ext[0], [0, 3, 0, 5, 0, 0, 0])


def test_ctc_perfect_prediction_low_loss():
    """Near-one-hot correct logprobs => tiny nll."""
    v = 4
    seq = [1, 0, 2, 0, 3]  # label 1,2,3 with blanks
    lp = np.full((len(seq), v), -20.0, np.float32)
    for t, c in enumerate(seq):
        lp[t, c] = -1e-4
    got = ctc_loss(
        jnp.asarray(lp)[None],
        jnp.asarray([len(seq)], jnp.int32),
        jnp.asarray([[1, 2, 3]], jnp.int32),
        jnp.asarray([3], jnp.int32),
    )
    assert float(got[0]) < 0.1


def test_ctc_mean_normalizes_by_label_len():
    rng = np.random.RandomState(2)
    lp = make_logprobs(rng, 6, 5)
    mean, nll = ctc_loss_mean(
        jnp.asarray(lp)[None],
        jnp.asarray([6], jnp.int32),
        jnp.asarray([[1, 2, 3]], jnp.int32),
        jnp.asarray([3], jnp.int32),
    )
    np.testing.assert_allclose(float(mean), float(nll[0]) / 3.0, rtol=1e-6)

"""L1 correctness: every Pallas kernel against its pure-jnp oracle.

Hypothesis sweeps shapes (including non-block-multiple raggedness) and
value ranges; this is the CORE correctness signal for the kernels that end
up inside every AOT artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)

dims = st.integers(min_value=1, max_value=67)
small_dims = st.integers(min_value=1, max_value=33)


def rng_array(seed, shape, scale=1.0, dtype=np.float32):
    r = np.random.RandomState(seed)
    return (r.standard_normal(shape) * scale).astype(dtype)


# --------------------------------------------------------------------------
# matmul_t
# --------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(m=dims, n=dims, k=dims, seed=st.integers(0, 2**31 - 1))
def test_matmul_t_matches_ref(m, n, k, seed):
    x = rng_array(seed, (m, k))
    w = rng_array(seed + 1, (n, k))
    got = kernels.matmul_t(jnp.asarray(x), jnp.asarray(w))
    want = ref.matmul_t_ref(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_matmul_t_block_multiple_shapes():
    x = rng_array(0, (16, 256))
    w = rng_array(1, (256, 256))
    got = kernels.matmul_t(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(
        np.asarray(got), x @ w.T, rtol=1e-4, atol=1e-4
    )


def test_matmul_t_gradients_match_dense():
    """The custom VJP must agree with jnp.dot's gradients."""
    x = jnp.asarray(rng_array(2, (4, 12)))
    w = jnp.asarray(rng_array(3, (9, 12)))

    def f_kernel(x, w):
        return jnp.sum(jnp.sin(kernels.matmul_t(x, w)))

    def f_ref(x, w):
        return jnp.sum(jnp.sin(x @ w.T))

    gx1, gw1 = jax.grad(f_kernel, argnums=(0, 1))(x, w)
    gx2, gw2 = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx1), np.asarray(gx2), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw1), np.asarray(gw2), rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------
# lowrank_apply
# --------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(m=small_dims, n=dims, k=dims, r=st.integers(1, 24), seed=st.integers(0, 2**31 - 1))
def test_lowrank_apply_matches_ref(m, n, k, r, seed):
    x = jnp.asarray(rng_array(seed, (m, k)))
    u = jnp.asarray(rng_array(seed + 1, (n, r)))
    v = jnp.asarray(rng_array(seed + 2, (r, k)))
    got = kernels.lowrank_apply(x, u, v)
    want = ref.lowrank_apply_ref(x, u, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_lowrank_apply_equals_full_product():
    """x @ (UV).T computed factored == computed via the materialized W."""
    x = jnp.asarray(rng_array(0, (5, 20)))
    u = jnp.asarray(rng_array(1, (17, 6)))
    v = jnp.asarray(rng_array(2, (6, 20)))
    w_full = np.asarray(u) @ np.asarray(v)
    got = kernels.lowrank_apply(x, u, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x) @ w_full.T, rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# gru_gates
# --------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 9), h=st.integers(1, 48), seed=st.integers(0, 2**31 - 1))
def test_gru_gates_matches_ref(b, h, seed):
    gx = jnp.asarray(rng_array(seed, (b, 3 * h), scale=2.0))
    gh = jnp.asarray(rng_array(seed + 1, (b, 3 * h), scale=2.0))
    hprev = jnp.asarray(rng_array(seed + 2, (b, h)))
    got = kernels.gru_gates(gx, gh, hprev)
    want = ref.gru_gates_ref(gx, gh, hprev)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_gru_gates_is_convex_combination():
    """|h'| is bounded by max(|h|, 1): h' is a convex combo of h and tanh."""
    gx = jnp.asarray(rng_array(0, (4, 24), scale=5.0))
    gh = jnp.asarray(rng_array(1, (4, 24), scale=5.0))
    h = jnp.asarray(rng_array(2, (4, 8), scale=0.5))
    out = np.asarray(kernels.gru_gates(gx, gh, h))
    bound = np.maximum(np.abs(np.asarray(h)), 1.0) + 1e-6
    assert (np.abs(out) <= bound).all()

def test_gru_gates_gradients_match_ref():
    gx = jnp.asarray(rng_array(3, (3, 12)))
    gh = jnp.asarray(rng_array(4, (3, 12)))
    h = jnp.asarray(rng_array(5, (3, 4)))

    g1 = jax.grad(lambda *a: jnp.sum(kernels.gru_gates(*a) ** 2), argnums=(0, 1, 2))(gx, gh, h)
    g2 = jax.grad(lambda *a: jnp.sum(ref.gru_gates_ref(*a) ** 2), argnums=(0, 1, 2))(gx, gh, h)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# int8_gemm
# --------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 8),
    n=dims,
    k=dims,
    seed=st.integers(0, 2**31 - 1),
)
def test_int8_gemm_matches_ref(m, n, k, seed):
    r = np.random.RandomState(seed)
    xq = r.randint(-127, 128, size=(m, k)).astype(np.int8)
    wq = r.randint(-127, 128, size=(n, k)).astype(np.int8)
    sx = jnp.asarray([abs(r.standard_normal()) * 0.01 + 1e-4], jnp.float32)
    sw = jnp.asarray([abs(r.standard_normal()) * 0.01 + 1e-4], jnp.float32)
    got = kernels.int8_gemm(jnp.asarray(xq), jnp.asarray(wq), sx, sw)
    want = ref.int8_gemm_ref(jnp.asarray(xq), jnp.asarray(wq), sx[0], sw[0])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_int8_gemm_exact_small():
    """Small integer products must be *exact* after dequant by 1.0."""
    xq = jnp.asarray([[1, 2, 3], [-4, 5, -6]], jnp.int8)
    wq = jnp.asarray([[1, 1, 1], [2, 0, -2]], jnp.int8)
    one = jnp.asarray([1.0], jnp.float32)
    got = np.asarray(kernels.int8_gemm(xq, wq, one, one))
    want = np.array([[6, -4], [-5, 4]], np.float32)
    np.testing.assert_array_equal(got, want)

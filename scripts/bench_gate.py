#!/usr/bin/env python3
"""Tolerance gate for the bench sweeps.

Usage: bench_gate.py BASELINE.json CURRENT.json [tolerance]

Compares every row of the current sweep against the committed baseline.
GEMM rows are keyed by (backend, kind, m); cascade rows (DESIGN.md §11)
by (pair, threshold).  Higher-is-better row metrics (``gops``,
``flops_reduction_vs_high``) may not regress below
``(1 - tol) * baseline``; latency-style scalars whose key ends in
``_secs`` or ``_ms`` (lower is better) may not exceed
``(1 + tol) * baseline``, and top-level scalars ending in ``_reduction``
(higher is better) may not fall below ``(1 - tol) * baseline``.
Improvements never fail the gate.

The baseline starts life as ``{"pending": true}`` (no toolchain on the
machine that authored it); the gate then passes with a warning so CI
stays green until ``scripts/bench_snapshot.sh`` is run on real hardware.
The tolerance defaults to 0.35 and can be overridden by the third
positional argument or the ``BENCH_GATE_TOL`` environment variable
(CI's smoke mode runs one iteration per case, so it uses a wider band).
"""

import json
import os
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def row_key(r):
    if "backend" in r and "kind" in r and "m" in r:
        return (r["backend"], r["kind"], int(r["m"]))
    if "pair" in r and "threshold" in r:
        return (r["pair"], str(r["threshold"]))
    return None


def rows_by_key(report):
    out = {}
    for r in report.get("results", []):
        key = row_key(r)
        if key is not None:
            out[key] = r
    return out


# per-row throughput-style metrics: higher is better
ROW_METRICS = ("gops", "flops_reduction_vs_high")


def main(argv):
    if len(argv) < 3:
        print(__doc__)
        return 2
    baseline_path, current_path = argv[1], argv[2]
    tol = float(argv[3]) if len(argv) > 3 else float(os.environ.get("BENCH_GATE_TOL", "0.35"))

    try:
        baseline = load(baseline_path)
    except FileNotFoundError:
        print(f"bench gate: no baseline at {baseline_path}; PASS (nothing to gate)")
        return 0
    if baseline.get("pending"):
        print("bench gate: BENCH GATE UNARMED — baseline is pending (run "
              "scripts/bench_snapshot.sh on real hardware to arm it); PASS with warning")
        return 0

    current = load(current_path)
    base_rows = rows_by_key(baseline)
    cur_rows = rows_by_key(current)

    failures = []
    compared = 0
    for key, base in sorted(base_rows.items()):
        cur = cur_rows.get(key)
        if cur is None:
            failures.append(f"{key}: row missing from current sweep")
            continue
        for metric in ROW_METRICS:
            if metric not in base:
                continue
            compared += 1
            b, c = base[metric], cur.get(metric, 0.0)
            if b > 0 and c < (1.0 - tol) * b:
                failures.append(f"{key}: {metric} {c:.3f} < {(1.0 - tol) * b:.3f} "
                                f"(baseline {b:.3f}, tol {tol:.0%})")

    # top-level scalars: *_secs / *_ms lower is better (pack costs etc.),
    # *_reduction higher is better (the cascade matched-CER figure)
    for k, b in baseline.items():
        if not isinstance(b, (int, float)) or isinstance(b, bool):
            continue
        c = current.get(k)
        if c is None:
            continue
        if k.endswith("_secs") or k.endswith("_ms"):
            compared += 1
            if b > 0 and c > (1.0 + tol) * b:
                failures.append(f"{k}: {c:.6f} > {(1.0 + tol) * b:.6f} "
                                f"(baseline {b:.6f}, tol {tol:.0%})")
        elif k.endswith("_reduction"):
            compared += 1
            if b > 0 and c < (1.0 - tol) * b:
                failures.append(f"{k}: {c:.3f} < {(1.0 - tol) * b:.3f} "
                                f"(baseline {b:.3f}, tol {tol:.0%})")

    if failures:
        print(f"bench gate: {len(failures)} regression(s) past the {tol:.0%} band:")
        for f in failures:
            print(f"  FAIL {f}")
        return 1
    print(f"bench gate: {compared} metrics within the {tol:.0%} band; PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))

#!/usr/bin/env bash
# CI entry point: format, lint, tier-1 verify, bench smoke.
#
# Everything runs offline against the default feature set (no xla); the
# bench smoke sets BENCH_SMOKE=1 so each bench binary executes exactly
# one timed iteration per case (see rust/benches/harness.rs).

set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy -D warnings (default + simd)"
cargo clippy --all-targets -- -D warnings
cargo clippy --all-targets --features simd -- -D warnings

echo "==> tier-1 verify: cargo build --release && cargo test -q"
cargo build --release
cargo test -q

echo "==> backend parity suite (int8 + int4) under --features simd"
cargo build --release --features simd
cargo test -q --features simd --test backends
cargo test -q --features simd --test properties
cargo test -q --features simd --test alloc_free
cargo test -q --features simd --lib kernels

echo "==> rustdoc (no warnings allowed)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "==> native-train smoke: 2-epoch stage-1 loss must decrease; checkpoint must serve"
ldir="$(mktemp -d)"
ndir="$(mktemp -d)"
trap 'rm -rf "$ldir" "$ndir"' EXIT
cargo run --release -q -- train --native --stage 1 --epochs 2 --utts 24 --dev-utts 4 \
  --batch 4 --seed 7 --save "$ndir/stage1.tnck" | tee "$ndir/native.log"
grep -q "stage1 loss decreased: true" "$ndir/native.log" \
  || { echo "native-train smoke: stage-1 loss did not decrease"; exit 1; }
cargo run --release -q -- train --native --stage 2 --epochs 1 --utts 24 --dev-utts 4 \
  --batch 4 --seed 7 --load "$ndir/stage1.tnck" --save "$ndir/stage2.tnck" \
  > "$ndir/stage2.log"
grep -q "saved train-state" "$ndir/stage2.log" \
  || { echo "native-train smoke: stage-2 save failed"; exit 1; }
cargo run --release -q -- ladder-build --out "$ndir/ladder" --fracs 0.5 \
  --load "$ndir/stage2.tnck" > "$ndir/ladder.log"
grep -q "dims from its meta block" "$ndir/ladder.log" \
  || { echo "native-train smoke: ladder-build did not consume the train-state"; exit 1; }

echo "==> int4 QAT smoke: stage-2 --bits 4 fine-tune; int4 ladder must serve"
# Quantization-aware stage 2 trains through the serving int4 quantizer
# (straight-through estimator); the result must quantize into an int4
# rung that the adaptive-fidelity serve loads and runs.
cargo run --release -q -- train --native --stage 2 --epochs 2 --utts 24 --dev-utts 4 \
  --batch 4 --seed 7 --bits 4 --load "$ndir/stage1.tnck" --save "$ndir/stage2q.tnck" \
  | tee "$ndir/stage2q.log"
grep -q "QAT int4" "$ndir/stage2q.log" \
  || { echo "int4 QAT smoke: trainer did not report QAT"; exit 1; }
grep -q "stage2 loss decreased: true" "$ndir/stage2q.log" \
  || { echo "int4 QAT smoke: stage-2 loss did not decrease under QAT"; exit 1; }
cargo run --release -q -- ladder-build --out "$ndir/ladder4" --fracs 0.5 --bits 4 \
  --load "$ndir/stage2q.tnck" > "$ndir/ladder4.log"
grep -q "int4 weights" "$ndir/ladder4.log" \
  || { echo "int4 QAT smoke: ladder-build did not build int4 rungs"; exit 1; }
cargo run --release -q -- stream-serve --ladder "$ndir/ladder4" --utts 6 --rate 1000 \
  --pool 2 --chunk 8 --seed 7 > "$ndir/serve4.log"
grep -q "bits 4" "$ndir/serve4.log" \
  || { echo "int4 QAT smoke: ladder serve did not report int4 tiers"; exit 1; }

echo "==> sharded smoke: stream-serve --shards 2 --json + report sanity"
sj="$(cargo run --release -q -- stream-serve --shards 2 --utts 12 --rate 1000 \
  --pool 2 --chunk 8 --seed 7 --json)"
echo "$sj" | grep -q '"kind": "stream-serve"' \
  || { echo "sharded smoke: --json did not emit a stream-serve report"; exit 1; }
echo "$sj" | grep -q '"shards": 2' \
  || { echo "sharded smoke: report does not carry the shard count"; exit 1; }
echo "$sj" | grep -q '"p99"' \
  || { echo "sharded smoke: latency summary missing"; exit 1; }
echo "$sj" | grep -q '"shard": 1' \
  || { echo "sharded smoke: per-shard slice for shard 1 missing"; exit 1; }
if command -v python3 >/dev/null 2>&1; then
  echo "$sj" | python3 -m json.tool >/dev/null \
    || { echo "sharded smoke: --json output is not valid JSON"; exit 1; }
fi

echo "==> fused-gates smoke: stream-serve default vs --features simd, on and off"
# The fused GRU-gate kernel and the m=1 GEMV path are bit-identical to the
# plain farm sweep by construction; this smoke proves the serving path runs
# end-to-end with fusion on (default) and off, under both builds, and that
# the report advertises the switch.
for build in "" "--features simd"; do
  for fused in on off; do
    fj="$(cargo run --release -q $build -- stream-serve --utts 8 --rate 1000 \
      --pool 2 --chunk 8 --seed 7 --fused-gates "$fused" --autotune off --json)"
    echo "$fj" | grep -q '"kind": "stream-serve"' \
      || { echo "fused smoke: no report (build='$build' fused=$fused)"; exit 1; }
    want=$([ "$fused" = on ] && echo true || echo false)
    echo "$fj" | grep -q "\"fused_gates\": $want" \
      || { echo "fused smoke: report fused_gates != $want (build='$build')"; exit 1; }
  done
done

echo "==> int4 serve smoke: --bits 4 under default and --features simd"
# The packed sub-byte path must serve end to end on every build, and the
# JSON report must say so (engine/pool transcripts are bit-identical
# across backends by the parity suite above).
for build in "" "--features simd"; do
  qj="$(cargo run --release -q $build -- stream-serve --utts 8 --rate 1000 \
    --pool 2 --chunk 8 --seed 7 --bits 4 --autotune off --json)"
  echo "$qj" | grep -q '"kind": "stream-serve"' \
    || { echo "int4 smoke: no report (build='$build')"; exit 1; }
  echo "$qj" | grep -q '"precision": "int4"' \
    || { echo "int4 smoke: report precision != int4 (build='$build')"; exit 1; }
done

echo "==> obs smoke: flight recorder report + JSONL metrics stream"
# --obs on must surface the self-time span breakdown, kernel counters and
# the event journal in the JSON report, and --metrics-out must emit one
# valid JSON object per line with the versioned envelope (DESIGN.md §10).
oj="$(cargo run --release -q -- stream-serve --utts 4 --rate 1000 --pool 2 --chunk 8 \
  --seed 7 --obs on --metrics-out "$ndir/metrics.jsonl" --json)"
echo "$oj" | grep -q '"schema_version": 1' \
  || { echo "obs smoke: --json report missing schema_version"; exit 1; }
echo "$oj" | grep -q '"obs"' \
  || { echo "obs smoke: --json report missing the obs block"; exit 1; }
echo "$oj" | grep -q '"spans"' \
  || { echo "obs smoke: obs block missing the span breakdown"; exit 1; }
echo "$oj" | grep -q '"journal"' \
  || { echo "obs smoke: obs block missing the event journal"; exit 1; }
test -s "$ndir/metrics.jsonl" || { echo "obs smoke: --metrics-out wrote nothing"; exit 1; }
grep -q '"schema_version":1' "$ndir/metrics.jsonl" \
  || { echo "obs smoke: JSONL snapshots missing schema_version"; exit 1; }
if command -v python3 >/dev/null 2>&1; then
  while IFS= read -r line; do
    printf '%s' "$line" | python3 -m json.tool >/dev/null \
      || { echo "obs smoke: invalid JSONL line: $line"; exit 1; }
  done < "$ndir/metrics.jsonl"
fi
cargo run --release -q -- stream-serve --utts 4 --rate 1000 --pool 2 --chunk 8 \
  --seed 7 --obs on > "$ndir/obs_text.log"
grep -q "self-time" "$ndir/obs_text.log" \
  || { echo "obs smoke: text report missing the self-time table"; exit 1; }

echo "==> ladder smoke: 2-rung build + ramped adaptive-fidelity serve"
cargo run --release -q -- ladder-build --out "$ldir" --fracs 0.5,0.25 --seed 7
report="$(cargo run --release -q -- stream-serve --ladder "$ldir" --utts 10 --ramp-utts 6 \
  --ramp-rate 1000000 --rate 0.001 --pool 2 --chunk 8 --seed 7)"
echo "$report"
echo "$report" | grep -q "tier 0" || { echo "ladder smoke: per-tier report missing tier 0"; exit 1; }
echo "$report" | grep -q "tier 1" || { echo "ladder smoke: per-tier report missing tier 1"; exit 1; }
echo "$report" | grep -q "fidelity shifts" || { echo "ladder smoke: shift summary missing"; exit 1; }

echo "==> cascade smoke: 2-rung ladder + confidence-gated cascade serve"
# The cascade decodes low-tier blocks on the cheap rung and escalates
# low-confidence blocks to the high rung (DESIGN.md §11).  Both builds
# must serve end to end, print the escalation-rate line, and emit a
# valid --json report with the cascade summary; the plain (synthetic
# rank-fraction pair) form must run too.
cargo run --release -q -- ladder-build --out "$ldir-casc" --fracs 0.5,0.25 --seed 7
for build in "" "--features simd"; do
  crep="$(cargo run --release -q $build -- stream-serve --ladder "$ldir-casc" \
    --cascade 1:0 --escalate-threshold inf --utts 8 --ramp-utts 6 --ramp-rate 1000000 \
    --rate 0.001 --pool 2 --chunk 8 --seed 7)"
  echo "$crep" | grep -q "escalation-rate" \
    || { echo "cascade smoke: escalation-rate line missing (build='$build')"; exit 1; }
  echo "$crep" | grep -q "GFLOP/frame" \
    || { echo "cascade smoke: effective-FLOPs line missing (build='$build')"; exit 1; }
  cjson="$(cargo run --release -q $build -- stream-serve --ladder "$ldir-casc" \
    --cascade 1:0 --escalate-threshold inf --utts 8 --ramp-utts 6 --ramp-rate 1000000 \
    --rate 0.001 --pool 2 --chunk 8 --seed 7 --json)"
  echo "$cjson" | grep -q '"cascade"' \
    || { echo "cascade smoke: --json report missing the cascade block (build='$build')"; exit 1; }
  echo "$cjson" | grep -q '"escalation_rate"' \
    || { echo "cascade smoke: --json cascade block missing escalation_rate (build='$build')"; exit 1; }
  if command -v python3 >/dev/null 2>&1; then
    echo "$cjson" | python3 -m json.tool >/dev/null \
      || { echo "cascade smoke: --json output is not valid JSON (build='$build')"; exit 1; }
  fi
done
pcrep="$(cargo run --release -q -- stream-serve --cascade 0.25:0.75 --escalate-threshold 0.1 \
  --utts 6 --rate 1000 --pool 2 --chunk 8 --seed 7)"
echo "$pcrep" | grep -q "escalation-rate" \
  || { echo "cascade smoke: plain-path escalation-rate line missing"; exit 1; }
rm -rf "$ldir-casc"

echo "==> trace/SLO smoke: --trace-out + --slo-target + obs-report round trip"
# A fixed-tick ladder serve writes both a Perfetto trace and a JSONL;
# obs-report must replay the JSONL into the same summary tables and
# re-emit the identical trace bytes from the JSONL alone (DESIGN.md §10).
cargo run --release -q -- stream-serve --ladder "$ldir" --utts 8 --rate 1000 \
  --pool 2 --chunk 8 --seed 7 --obs on --fixed-tick-ms 4 --slo-target 250 \
  --metrics-out "$ndir/slo.jsonl" --trace-out "$ndir/trace.json" > "$ndir/slo.log"
grep -q "SLO:" "$ndir/slo.log" \
  || { echo "trace smoke: serve report missing the SLO line"; exit 1; }
test -s "$ndir/trace.json" || { echo "trace smoke: --trace-out wrote nothing"; exit 1; }
grep -q '"ph":"X"' "$ndir/trace.json" \
  || { echo "trace smoke: trace carries no pump-block slices"; exit 1; }
if command -v python3 >/dev/null 2>&1; then
  python3 -m json.tool "$ndir/trace.json" >/dev/null \
    || { echo "trace smoke: trace is not valid JSON"; exit 1; }
fi
orep="$(cargo run --release -q -- obs-report "$ndir/slo.jsonl" --trace-out "$ndir/trace2.json")"
echo "$orep" | grep -q "SLO attainment" \
  || { echo "trace smoke: obs-report missing the SLO attainment table"; exit 1; }
echo "$orep" | grep -q "self-time" \
  || { echo "trace smoke: obs-report missing the self-time breakdown"; exit 1; }
cmp -s "$ndir/trace.json" "$ndir/trace2.json" \
  || { echo "trace smoke: obs-report re-emission differs from the live trace"; exit 1; }

echo "==> bench smoke (1 iteration each)"
# so the emit checks below cannot pass on stale files
rm -f BENCH_gemm.json BENCH_train.json BENCH_shard.json BENCH_cascade.json
for b in gemm linalg streaming stream_pool shard ladder coordinator train cascade; do
  echo "--- bench $b"
  BENCH_SMOKE=1 cargo bench --bench "$b"
done
test -f BENCH_gemm.json || { echo "gemm bench did not emit BENCH_gemm.json"; exit 1; }
grep -q '"backend": "blocked"' BENCH_gemm.json \
  || { echo "BENCH_gemm.json missing the blocked-backend sweep"; exit 1; }
grep -q '"kind": "qgemv"' BENCH_gemm.json \
  || { echo "BENCH_gemm.json missing the m=1 GEMV sweep"; exit 1; }
grep -q '"kind": "qgemm_gates"' BENCH_gemm.json \
  || { echo "BENCH_gemm.json missing the fused-gates sweep"; exit 1; }
grep -q '"kind": "qgemv4"' BENCH_gemm.json \
  || { echo "BENCH_gemm.json missing the int4 m=1 GEMV sweep"; exit 1; }
grep -q '"kind": "qgemm4_gates"' BENCH_gemm.json \
  || { echo "BENCH_gemm.json missing the int4 fused-gates sweep"; exit 1; }
grep -q '"bytes_per_weight": 0.625' BENCH_gemm.json \
  || { echo "BENCH_gemm.json int4 rows missing the 0.625 bytes/weight axis"; exit 1; }
test -f BENCH_train.json || { echo "train bench did not emit BENCH_train.json"; exit 1; }
grep -q '"kind": "ctc"' BENCH_train.json \
  || { echo "BENCH_train.json missing the CTC lattice sweep"; exit 1; }
test -f BENCH_shard.json || { echo "shard bench did not emit BENCH_shard.json"; exit 1; }
grep -q '"shards": 4' BENCH_shard.json \
  || { echo "BENCH_shard.json missing the 4-shard sweep row"; exit 1; }
test -f BENCH_cascade.json || { echo "cascade bench did not emit BENCH_cascade.json"; exit 1; }
grep -q '"matched_cer_flops_reduction"' BENCH_cascade.json \
  || { echo "BENCH_cascade.json missing the matched-CER reduction figure"; exit 1; }
grep -q '"gflops_effective"' BENCH_cascade.json \
  || { echo "BENCH_cascade.json missing the effective-FLOPs curve rows"; exit 1; }
if command -v python3 >/dev/null 2>&1; then
  python3 -m json.tool BENCH_cascade.json >/dev/null \
    || { echo "BENCH_cascade.json is not valid JSON"; exit 1; }
fi

echo "==> bench tolerance gate vs BENCH_BASELINE.json"
# Smoke-mode numbers are noisy; the gate uses a wide tolerance and is
# advisory until a real baseline is snapshotted (scripts/bench_snapshot.sh).
if command -v python3 >/dev/null 2>&1; then
  python3 ../scripts/bench_gate.py ../BENCH_BASELINE.json BENCH_gemm.json \
    || { echo "bench gate failed"; exit 1; }
  # the cascade curve gates against its own committed snapshot (absent
  # until bench_snapshot.sh runs on real hardware -> PASS with a note)
  python3 ../scripts/bench_gate.py ../BENCH_cascade.json BENCH_cascade.json \
    || { echo "cascade bench gate failed"; exit 1; }
else
  echo "BENCH GATE UNARMED: python3 unavailable; skipping bench gate"
fi

echo "CI OK"

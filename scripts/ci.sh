#!/usr/bin/env bash
# CI entry point: format, lint, tier-1 verify, bench smoke.
#
# Everything runs offline against the default feature set (no xla); the
# bench smoke sets BENCH_SMOKE=1 so each bench binary executes exactly
# one timed iteration per case (see rust/benches/harness.rs).

set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy -D warnings"
cargo clippy --all-targets -- -D warnings

echo "==> tier-1 verify: cargo build --release && cargo test -q"
cargo build --release
cargo test -q

echo "==> rustdoc (no warnings allowed)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "==> bench smoke (1 iteration each)"
for b in gemm linalg streaming stream_pool coordinator; do
  echo "--- bench $b"
  BENCH_SMOKE=1 cargo bench --bench "$b"
done

echo "CI OK"

#!/usr/bin/env bash
# CI entry point: format, lint, tier-1 verify, bench smoke.
#
# Everything runs offline against the default feature set (no xla); the
# bench smoke sets BENCH_SMOKE=1 so each bench binary executes exactly
# one timed iteration per case (see rust/benches/harness.rs).

set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy -D warnings (default + simd)"
cargo clippy --all-targets -- -D warnings
cargo clippy --all-targets --features simd -- -D warnings

echo "==> tier-1 verify: cargo build --release && cargo test -q"
cargo build --release
cargo test -q

echo "==> backend parity suite under --features simd"
cargo build --release --features simd
cargo test -q --features simd --test backends
cargo test -q --features simd --test properties
cargo test -q --features simd --test alloc_free
cargo test -q --features simd --lib kernels

echo "==> rustdoc (no warnings allowed)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "==> ladder smoke: 2-rung build + ramped adaptive-fidelity serve"
ldir="$(mktemp -d)"
trap 'rm -rf "$ldir"' EXIT
cargo run --release -q -- ladder-build --out "$ldir" --fracs 0.5,0.25 --seed 7
report="$(cargo run --release -q -- stream-serve --ladder "$ldir" --utts 10 --ramp-utts 6 \
  --ramp-rate 1000000 --rate 0.001 --pool 2 --chunk 8 --seed 7)"
echo "$report"
echo "$report" | grep -q "tier 0" || { echo "ladder smoke: per-tier report missing tier 0"; exit 1; }
echo "$report" | grep -q "tier 1" || { echo "ladder smoke: per-tier report missing tier 1"; exit 1; }
echo "$report" | grep -q "fidelity shifts" || { echo "ladder smoke: shift summary missing"; exit 1; }

echo "==> bench smoke (1 iteration each)"
rm -f BENCH_gemm.json # so the emit check below cannot pass on a stale file
for b in gemm linalg streaming stream_pool ladder coordinator; do
  echo "--- bench $b"
  BENCH_SMOKE=1 cargo bench --bench "$b"
done
test -f BENCH_gemm.json || { echo "gemm bench did not emit BENCH_gemm.json"; exit 1; }
grep -q '"backend": "blocked"' BENCH_gemm.json \
  || { echo "BENCH_gemm.json missing the blocked-backend sweep"; exit 1; }

echo "CI OK"

#!/usr/bin/env bash
# Snapshot the GEMM bench sweep into BENCH_BASELINE.json at the repo root.
#
# Run this on the hardware that CI benches on, at full iteration counts
# (no BENCH_SMOKE), so the committed baseline reflects real steady-state
# numbers.  scripts/bench_gate.py then fails CI when a future sweep
# regresses past its tolerance band (default 35% relative; gops rows are
# higher-is-better, *_secs / *_ms scalars are lower-is-better).
#
# Usage: scripts/bench_snapshot.sh [--features simd]

set -euo pipefail
cd "$(dirname "$0")/../rust"

out="$(cd .. && pwd)/BENCH_BASELINE.json"
tmp="$(mktemp)"
trap 'rm -f "$tmp"' EXIT

echo "==> full gemm sweep ($*)"
BENCH_GEMM_JSON="$tmp" cargo bench --bench gemm "$@"

if command -v python3 >/dev/null 2>&1; then
  python3 -m json.tool "$tmp" >/dev/null || { echo "sweep emitted invalid JSON"; exit 1; }
fi
cp "$tmp" "$out"
echo "wrote baseline to $out"
echo "commit it so scripts/bench_gate.py arms the CI tolerance gate"

# Cascade CER-vs-effective-FLOPs curve (DESIGN.md §11): full iteration
# counts, committed next to the baseline so the matched-CER FLOPs
# reduction (acceptance floor 1.5x) is tracked across commits.
cascade_out="$(cd .. && pwd)/BENCH_cascade.json"
echo "==> cascade sweep (CER vs effective FLOPs per rung pair)"
BENCH_CASCADE_JSON="$cascade_out" cargo bench --bench cascade "$@"
if command -v python3 >/dev/null 2>&1; then
  python3 -m json.tool "$cascade_out" >/dev/null \
    || { echo "cascade sweep emitted invalid JSON"; exit 1; }
fi
echo "wrote cascade curve to $cascade_out"

# Alongside the kernel baseline, record a flight-recorder span snapshot:
# a short obs-on serve whose JSONL metrics stream (stage self-time
# breakdown + kernel counters, DESIGN.md §10) lands next to the baseline
# so span-share drift across machines/commits is diffable.
spans_out="$(cd .. && pwd)/BENCH_SPANS.jsonl"
echo "==> obs span snapshot (stream-serve --obs on)"
cargo run --release -q "$@" -- stream-serve --utts 8 --rate 1000 --pool 2 --chunk 8 \
  --seed 7 --obs on --metrics-out "$spans_out" > /dev/null
if command -v python3 >/dev/null 2>&1; then
  while IFS= read -r line; do
    printf '%s' "$line" | python3 -m json.tool >/dev/null \
      || { echo "span snapshot emitted an invalid JSONL line"; exit 1; }
  done < "$spans_out"
fi
echo "wrote span snapshot to $spans_out"

# Trace-export overhead row: the same obs-on serve with and without
# --trace-out, wall-timed, so the cost of assembling and writing the
# Perfetto trace is tracked next to the kernel baseline (lower is
# better; *_secs scalars are gated by scripts/bench_gate.py).
trace_out="$(cd .. && pwd)/BENCH_trace.json"
echo "==> trace-export overhead (obs on vs obs + --trace-out)"
t0=$(date +%s.%N)
cargo run --release -q "$@" -- stream-serve --utts 8 --rate 1000 --pool 2 --chunk 8 \
  --seed 7 --obs on > /dev/null
t1=$(date +%s.%N)
cargo run --release -q "$@" -- stream-serve --utts 8 --rate 1000 --pool 2 --chunk 8 \
  --seed 7 --obs on --trace-out "$tmp.trace" > /dev/null
t2=$(date +%s.%N)
awk -v a="$t0" -v b="$t1" -v c="$t2" 'BEGIN {
  printf "{\"kind\": \"trace-export-overhead\", \"obs_secs\": %.6f, \"obs_trace_secs\": %.6f, \"trace_overhead_secs\": %.6f}\n",
    b - a, c - b, (c - b) - (b - a)
}' > "$trace_out"
rm -f "$tmp.trace"
echo "BENCH trace-export overhead: $(cat "$trace_out")"

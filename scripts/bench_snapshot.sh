#!/usr/bin/env bash
# Snapshot the GEMM bench sweep into BENCH_BASELINE.json at the repo root.
#
# Run this on the hardware that CI benches on, at full iteration counts
# (no BENCH_SMOKE), so the committed baseline reflects real steady-state
# numbers.  scripts/bench_gate.py then fails CI when a future sweep
# regresses past its tolerance band (default 35% relative; gops rows are
# higher-is-better, *_secs / *_ms scalars are lower-is-better).
#
# Usage: scripts/bench_snapshot.sh [--features simd]

set -euo pipefail
cd "$(dirname "$0")/../rust"

out="$(cd .. && pwd)/BENCH_BASELINE.json"
tmp="$(mktemp)"
trap 'rm -f "$tmp"' EXIT

echo "==> full gemm sweep ($*)"
BENCH_GEMM_JSON="$tmp" cargo bench --bench gemm "$@"

if command -v python3 >/dev/null 2>&1; then
  python3 -m json.tool "$tmp" >/dev/null || { echo "sweep emitted invalid JSON"; exit 1; }
fi
cp "$tmp" "$out"
echo "wrote baseline to $out"
echo "commit it so scripts/bench_gate.py arms the CI tolerance gate"

//! Serving demo: dynamic batching on the PJRT server path — throughput vs
//! latency as arrival rate and batch cap vary (the §4 batch-size story
//! from the server's side).
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_demo
//! ```

use tracenorm::data::{CorpusSpec, Dataset};
use tracenorm::error::Result;
use tracenorm::model::ParamSet;
use tracenorm::runtime::Runtime;
use tracenorm::serve::{simulate, ServeConfig};

fn main() -> Result<()> {
    let rt = Runtime::open("artifacts")?;
    let data = Dataset::generate(CorpusSpec::standard(77), 8, 8, 64);
    let spec = rt.manifest().artifact("eval_mini_unfact")?.clone();
    let params = ParamSet::init(&spec, 0)?; // weights don't affect timing

    println!("serving sim: {} requests through eval_mini_unfact (batch cap sweep)\n", data.test.len());
    println!(
        "{:>8} {:>9} {:>10} {:>10} {:>9} {:>9} {:>9}",
        "rate/s", "maxbatch", "thruput/s", "meanbatch", "p50 ms", "p95 ms", "p99 ms"
    );
    for &rate in &[5.0, 20.0, 60.0] {
        for &max_batch in &[1usize, 4, 8] {
            let cfg = ServeConfig { arrival_rate: rate, max_batch, window: 0.02, seed: 4 };
            let r = simulate(&rt, "eval_mini_unfact", &params, &data.test, &cfg)?;
            println!(
                "{:>8.0} {:>9} {:>10.1} {:>10.2} {:>9.1} {:>9.1} {:>9.1}",
                rate,
                max_batch,
                r.throughput,
                r.mean_batch,
                r.p50_latency * 1e3,
                r.p95_latency * 1e3,
                r.p99_latency * 1e3
            );
        }
    }
    println!("\n(batching lifts throughput at high arrival rates at the cost of queueing latency\n — the embedded path instead runs batch-1/time-batched, see embedded_demo)");
    Ok(())
}

//! Quickstart: load AOT artifacts, train a small factored model for a few
//! epochs through the PJRT runtime, and transcribe held-out utterances.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use tracenorm::data::{Batcher, CorpusSpec, Dataset};
use tracenorm::error::Result;
use tracenorm::runtime::Runtime;
use tracenorm::train::{eval_name, Evaluator, TrainOpts, Trainer};

fn main() -> Result<()> {
    // 1. open the artifact directory (L2's AOT output)
    let rt = Runtime::open("artifacts")?;
    println!(
        "loaded manifest: {} artifacts, alphabet of {}",
        rt.manifest().artifacts.len(),
        rt.manifest().alphabet.len()
    );

    // 2. generate the synthetic corpus (the WSJ stand-in)
    let data = Dataset::generate(CorpusSpec::standard(42), 128, 24, 8);
    println!("corpus: {} train / {} dev / {} test utterances", data.train.len(), data.dev.len(), data.test.len());

    // 3. train the paper's stage-1 model (factored, trace-norm surrogate)
    let artifact = "train_mini_partial_full";
    let spec = rt.manifest().artifact(artifact)?.clone();
    let opts = TrainOpts {
        seed: 0,
        lr: 2e-3,
        lr_decay: 0.95,
        epochs: 6,
        lam_rec: 3e-4,
        lam_nonrec: 3e-4,
        quiet: false,
    };
    let mut batcher = Batcher::new(&data.train, spec.batch.unwrap(), data.spec.feat_dim, 0);
    let eval = Evaluator::new(&rt, &eval_name(artifact))?;
    println!("\ntraining {artifact} with trace-norm regularization:");
    let mut trainer = Trainer::new(&rt, artifact, opts)?;
    trainer.run(&mut batcher, Some(&eval), Some(&data.dev))?;

    // 4. transcribe test utterances
    println!("\ntranscriptions (greedy decode):");
    for (logp, len, reference) in eval.logprobs(&trainer.params, &data.test)? {
        let hyp = tracenorm::decoder::transcript_greedy(&logp, len);
        println!("  ref: {reference:<16} hyp: {hyp}");
    }
    let stats = eval.greedy_cer(&trainer.params, &data.test)?;
    println!("\ntest CER {:.3}  WER {:.3}", stats.cer(), stats.wer());
    Ok(())
}

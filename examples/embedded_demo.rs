//! Embedded-engine demo: f32 vs int8, time-batching sweep, device
//! projections — the paper's §4 story on one utterance set.
//!
//! ```bash
//! make artifacts && cargo run --release --example embedded_demo
//! ```

use tracenorm::data::{Batcher, CorpusSpec, Dataset};
use tracenorm::devicesim;
use tracenorm::error::Result;
use tracenorm::infer::{Breakdown, Engine, Precision};
use tracenorm::kernels::GemmCounts;
use tracenorm::runtime::Runtime;
use tracenorm::train::{TrainOpts, Trainer};

fn main() -> Result<()> {
    let rt = Runtime::open("artifacts")?;
    let data = Dataset::generate(CorpusSpec::standard(9), 96, 16, 24);

    // quick low-rank model (the deployment-grade shape)
    let artifact = "train_mini_partial_r250";
    let spec = rt.manifest().artifact(artifact)?.clone();
    let mut batcher = Batcher::new(&data.train, spec.batch.unwrap(), data.spec.feat_dim, 0);
    let opts = TrainOpts { seed: 3, lr: 2e-3, lr_decay: 0.94, epochs: 6, quiet: false, ..Default::default() };
    println!("training a rank-0.25 model for the demo...");
    let mut t = Trainer::new(&rt, artifact, opts)?;
    t.run(&mut batcher, None, None)?;

    let dims = rt.manifest().dims("wsj_mini")?.clone();
    println!("\n== precision comparison ==");
    println!(
        "{:>6} {:>10} {:>8} {:>10} {:>12}",
        "mode", "model KB", "CER", "host RT-x", "ms/utt (AM)"
    );
    let mut int8_bd = Breakdown::default();
    for precision in [Precision::F32, Precision::Int8] {
        let engine = Engine::from_params(&dims, "partial", &t.params, precision, 4)?;
        let mut bd = Breakdown::default();
        let mut stats = tracenorm::decoder::ErrorStats::default();
        for u in &data.test {
            let (hyp, _) = engine.transcribe(&u.feats, &mut bd)?;
            stats.push(&hyp, &u.text);
        }
        println!(
            "{:>6} {:>10} {:>8.3} {:>10.1} {:>12.2}",
            format!("{precision:?}"),
            engine.model_bytes() / 1024,
            stats.cer(),
            bd.speedup_over_realtime(0.01),
            bd.acoustic_total() * 1e3 / data.test.len() as f64,
        );
        if precision == Precision::Int8 {
            int8_bd = bd;
        }
    }

    println!("\n== time-batching sweep (non-recurrent GEMM batches across time) ==");
    println!("{:>12} {:>12} {:>12}", "time_batch", "ms/utt (AM)", "1st-chunk ms");
    for tb in [1usize, 2, 4, 8] {
        let engine = Engine::from_params(&dims, "partial", &t.params, Precision::Int8, tb)?;
        let mut bd = Breakdown::default();
        let mut first_chunk = 0.0;
        for u in &data.test {
            let mut state = engine.new_state();
            let t0 = std::time::Instant::now();
            // feed exactly one block to measure first-output latency
            let need = tb * dims.total_stride * dims.feat_dim;
            let take = need.min(u.feats.len());
            let _ = engine.stream(&mut state, &u.feats.data()[..take], &mut bd)?;
            first_chunk += t0.elapsed().as_secs_f64();
            let _ = engine.stream(&mut state, &u.feats.data()[take..], &mut bd)?;
            let _ = engine.flush(&mut state, &mut bd)?;
        }
        println!(
            "{:>12} {:>12.2} {:>12.3}",
            tb,
            bd.acoustic_total() * 1e3 / data.test.len() as f64,
            first_chunk * 1e3 / data.test.len() as f64
        );
    }

    println!("\n== device projections (int8, time_batch 4) ==");
    let engine = Engine::from_params(&dims, "partial", &t.params, Precision::Int8, 4)?;
    let counts = GemmCounts {
        macs: int8_bd.macs,
        bytes_read: engine.model_bytes() as u64 * int8_bd.frames / dims.total_stride as u64 / 4,
        bytes_written: 0,
    };
    let host = devicesim::host_device(50.0, 10.0);
    println!("{:>16} {:>10} {:>12}", "device", "RT-x", "bound");
    for dev in devicesim::ALL_EMBEDDED {
        let secs = dev.project_from_host(&counts, &host, int8_bd.acoustic_total());
        let rtx = int8_bd.frames as f64 * 0.01 / secs;
        let bound = if dev.memory_bound(&counts) { "memory" } else { "compute" };
        println!("{:>16} {:>10.2} {:>12}", dev.name, rtx, bound);
    }
    Ok(())
}

//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Exercises the full three-layer system on a real small workload:
//! * stage 1 — trace-norm-regularized factored training via the AOT PJRT
//!   train step (L1 Pallas kernels inside);
//! * transition — Rust-side SVD rank selection + balanced warmstart;
//! * stage 2 — low-rank training to convergence, loss/CER logged per epoch;
//! * deployment — int8 quantization + the farm-kernel embedded engine,
//!   verified against the PJRT eval path, with device projections.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_e2e
//! ```

use std::io::Write;

use tracenorm::data::{Batcher, CorpusSpec, Dataset};
use tracenorm::devicesim;
use tracenorm::error::Result;
use tracenorm::infer::{Breakdown, Engine, Precision};
use tracenorm::kernels::GemmCounts;
use tracenorm::runtime::Runtime;
use tracenorm::train::{
    eval_name, frac_tag, two_stage, Evaluator, Stage2Lr, TrainOpts,
};

fn main() -> Result<()> {
    let t_start = std::time::Instant::now();
    let rt = Runtime::open("artifacts")?;
    let data = Dataset::generate(CorpusSpec::standard(2026), 192, 48, 48);
    println!(
        "e2e: corpus {}+{}+{} utts, model wsj_mini (DS2-style, 3 GRUs)",
        data.train.len(),
        data.dev.len(),
        data.test.len()
    );

    let stage1_artifact = "train_mini_partial_full";
    let spec = rt.manifest().artifact(stage1_artifact)?.clone();
    let mut batcher = Batcher::new(&data.train, spec.batch.unwrap(), data.spec.feat_dim, 1);
    let opts = TrainOpts {
        seed: 7,
        lr: 2e-3,
        lr_decay: 0.94,
        epochs: 5, // stage 1 (overridden by two_stage transition)
        lam_rec: 3e-4,
        lam_nonrec: 3e-4,
        quiet: false,
    };

    println!("\n== two-stage training (transition at epoch 5 of 10) ==");
    let result = two_stage(
        &rt,
        &mut batcher,
        &data.dev,
        stage1_artifact,
        "train_mini_partial",
        0.9,
        5,
        10,
        opts,
        Stage2Lr::Continuation,
    )?;
    println!(
        "\nselected rank fraction {} -> {} params (stage 1 had {})",
        result.rank_frac,
        result.stage2.params.num_scalars(),
        result.stage1_params.num_scalars()
    );

    // loss/CER curve -> results/e2e_curve.csv
    std::fs::create_dir_all("results")?;
    let mut csv = std::fs::File::create("results/e2e_curve.csv")?;
    writeln!(csv, "epoch,stage,mean_loss,dev_cer")?;
    for log in result.stage1_history.iter() {
        writeln!(
            csv,
            "{},stage1,{:.5},{}",
            log.epoch,
            log.mean_loss,
            log.dev_cer.map(|c| format!("{c:.4}")).unwrap_or_default()
        )?;
    }
    for log in result.stage2.history.iter() {
        writeln!(
            csv,
            "{},stage2,{:.5},{}",
            log.epoch + result.stage1_history.len(),
            log.mean_loss,
            log.dev_cer.map(|c| format!("{c:.4}")).unwrap_or_default()
        )?;
    }
    println!("wrote results/e2e_curve.csv");

    // final test-set accuracy through the PJRT path
    let eval = Evaluator::new(
        &rt,
        &eval_name(&format!("train_mini_partial_{}", frac_tag(result.rank_frac))),
    )?;
    let stats = eval.greedy_cer(&result.stage2.params, &data.test)?;
    println!("\ntest CER {:.3}  WER {:.3}", stats.cer(), stats.wer());

    // deployment: int8 embedded engine with farm kernels
    println!("\n== embedded deployment (int8, farm kernels) ==");
    let dims = rt.manifest().dims("wsj_mini")?.clone();
    let engine =
        Engine::from_params(&dims, "partial", &result.stage2.params, Precision::Int8, 4)?;
    let mut bd = Breakdown::default();
    let mut stats8 = tracenorm::decoder::ErrorStats::default();
    for u in &data.test {
        let (hyp, _) = engine.transcribe(&u.feats, &mut bd)?;
        stats8.push(&hyp, &u.text);
    }
    println!(
        "int8 engine: model {} KB, test CER {:.3} (f32 path {:.3}), host {:.1}x realtime",
        engine.model_bytes() / 1024,
        stats8.cer(),
        stats.cer(),
        bd.speedup_over_realtime(0.01)
    );
    let counts = GemmCounts {
        macs: bd.macs,
        bytes_read: engine.model_bytes() as u64 * bd.frames / dims.total_stride as u64 / 4,
        bytes_written: 0,
    };
    let host = devicesim::host_device(50.0, 10.0);
    for dev in devicesim::ALL_EMBEDDED {
        let secs = dev.project_from_host(&counts, &host, bd.acoustic_total());
        let rtx = bd.frames as f64 * 0.01 / secs;
        println!("  projected {:<16} {:>6.2}x realtime", dev.name, rtx);
    }

    println!("\ne2e driver completed in {:.0}s", t_start.elapsed().as_secs_f64());
    Ok(())
}
